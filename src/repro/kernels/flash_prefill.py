"""FlashAttention-2 prefill kernel (Pallas/TPU): causal + sliding-window, GQA.

Used by the training / prefill path. Grid ``(B*Hq, n_q_blocks, n_kv_blocks)``
with VMEM online-softmax accumulation over the kv axis; fully-masked kv
blocks (beyond causal diagonal or outside the sliding window) are skipped via
``pl.when`` so the causal schedule does ~half the work, window schedules
O(window) work.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _prefill_kernel(
    q_ref,       # (1, bq, d)
    k_ref,       # (1, bk, d)
    v_ref,       # (1, bk, d)
    o_ref,       # (1, bq, d)
    acc_ref,     # VMEM (bq, d) f32
    m_acc_ref,   # VMEM (bq, 1)
    l_acc_ref,   # VMEM (bq, 1)
    *,
    scale: float,
    block_q: int,
    block_kv: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    kv_len: int,
):
    qb = pl.program_id(1)
    jb = pl.program_id(2)
    q_start = qb * block_q + q_offset          # absolute positions
    k_start = jb * block_kv

    @pl.when(jb == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
        l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

    # block-level relevance test
    relevant = k_start < kv_len
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= (k_start + block_kv - 1) > (q_start - window)

    @pl.when(relevant)
    def _work():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < kv_len
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_acc_ref[...] = alpha * l_acc_ref[...] + jnp.sum(
            p, axis=1, keepdims=True
        )
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_acc_ref[...] = m_new

    @pl.when(jb == pl.num_programs(2) - 1)
    def _flush():
        # rows with no attended keys (can't happen causally) guard: l>0
        l = jnp.maximum(l_acc_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_prefill(
    q: jax.Array,   # (B, Hq, Lq, d)
    k: jax.Array,   # (B, Hkv, Lk, d)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """FA-2 prefill. Lq/Lk padded to block multiples internally."""
    B, Hq, Lq, d = q.shape
    _, Hkv, Lk, _ = k.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, max(8, Lq))
    block_kv = min(block_kv, max(8, Lk))
    pq = (-Lq) % block_q
    pk = (-Lk) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    Lqp, Lkp = Lq + pq, Lk + pk

    qf = qp.reshape(B * Hq, Lqp, d)
    kf = kp.reshape(B * Hkv, Lkp, d)
    vf = vp.reshape(B * Hkv, Lkp, d)

    nq, nk = Lqp // block_q, Lkp // block_kv
    kernel = functools.partial(
        _prefill_kernel,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        causal=causal,
        window=window,
        q_offset=q_offset,
        kv_len=Lk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qb, jb: (h, qb, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, qb, jb: (h // g, jb, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, qb, jb: (h // g, jb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qb, jb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Lqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Lqp, d)[:, :, :Lq, :]


# ------------------------------------------------------------- paged chunks
def _prefill_paged_kernel(
    tbl_ref,     # (N*W,) scalar prefetch: flattened page tables
    qoff_ref,    # (N,)   scalar prefetch: absolute position of each chunk's q[0]
    q_ref,       # (1, bq, d)
    k_ref,       # (1, page_size, d)  fetched through the page table
    v_ref,       # (1, page_size, d)
    o_ref,       # (1, bq, d)
    acc_ref,     # VMEM (bq, d) f32
    m_acc_ref,   # VMEM (bq, 1)
    l_acc_ref,   # VMEM (bq, 1)
    *,
    scale: float,
    block_q: int,
    page_size: int,
    n_heads: int,
):
    nh = pl.program_id(0)
    qb = pl.program_id(1)
    jb = pl.program_id(2)
    n = nh // n_heads
    # runtime offsets: one trace serves every chunk depth of every prompt
    q_start = qoff_ref[n] + qb * block_q
    k_start = jb * page_size

    @pl.when(jb == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
        l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

    # causal block skip doubles as the length guard: pages holding only
    # positions beyond the chunk's last query are stale/unwritten and masked
    @pl.when(k_start <= q_start + block_q - 1)
    def _work():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (bq, page_size)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos <= qpos
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_acc_ref[...] = alpha * l_acc_ref[...] + jnp.sum(
            p, axis=1, keepdims=True
        )
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_acc_ref[...] = m_new

    @pl.when(jb == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_acc_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_prefill_paged(
    q: jax.Array,           # (N, Hq, C, d) one prompt chunk per row
    k_pool: jax.Array,      # (num_pages, Hkv, page_size, d)
    v_pool: jax.Array,
    page_tbls: jax.Array,   # (N, W) int32 page table rows
    q_offsets: jax.Array,   # (N,) int32 absolute position of each chunk's q[0]
    scale: Optional[float] = None,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """FA-2 chunked prefill *through the page table* (fixed-grid baseline).

    The paged twin of :func:`flash_prefill` for the continuous-batching
    scheduler: each pack row is one prompt chunk whose KV — everything
    prefilled so far plus the chunk itself, already appended via
    :func:`repro.core.attention.paged_scatter_tokens` — lives in the global
    page pool. The kv grid axis walks the page-table width and a scalar-
    prefetch operand routes block ``j`` to flattened pool row
    ``tbl[n, j] * H_kv + head``; ``q_offsets`` is a runtime operand so one
    trace serves every chunk of every prompt (jit-stable static chunk
    geometry). Causal masking against absolute positions subsumes the
    length mask: stale data in partially-filled or unwritten pages always
    sits at key positions greater than every valid query position.

    Chunk-padding q rows produce garbage outputs the caller discards.
    """
    N, Hq, C, d = q.shape
    num_pages, Hkv, page_size, _ = k_pool.shape
    W = page_tbls.shape[1]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, max(8, C))
    pq = (-C) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    Cp = C + pq
    qf = qp.reshape(N * Hq, Cp, d)
    k_rows = k_pool.reshape(num_pages * Hkv, page_size, d)
    v_rows = v_pool.reshape(num_pages * Hkv, page_size, d)
    nq = Cp // block_q

    def kv_map(nh, qb, jb, tbl, qoff):
        return (tbl[(nh // Hq) * W + jb] * Hkv + (nh % Hq) // g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N * Hq, nq, W),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda nh, qb, jb, *_: (nh, qb, 0)),
            pl.BlockSpec((1, page_size, d), kv_map),
            pl.BlockSpec((1, page_size, d), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, d), lambda nh, qb, jb, *_: (nh, qb, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _prefill_paged_kernel,
        scale=scale, block_q=block_q, page_size=page_size, n_heads=Hq,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N * Hq, Cp, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        page_tbls.reshape(-1).astype(jnp.int32),
        q_offsets.astype(jnp.int32),
        qf, k_rows, v_rows,
    )
    return out.reshape(N, Hq, Cp, d)[:, :, :C, :]
