"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth in kernel sweeps (tests/test_kernels_*.py):
each kernel output must ``assert_allclose`` against its oracle over a grid
of shapes/dtypes, including ragged context lengths.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.attention import (
    mha_decode_ref,
    mha_prefill_ref,
    fixed_split_decode,
)


def lean_decode_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    ctx_lens: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """The lean kernel computes *exact* attention; oracle = standard decode."""
    return mha_decode_ref(q, k, v, ctx_lens=ctx_lens, scale=scale)


def flash_decode_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    ctx_lens: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Fixed-split also computes exact attention; same oracle."""
    return mha_decode_ref(q, k, v, ctx_lens=ctx_lens, scale=scale)


def flash_prefill_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    return mha_prefill_ref(
        q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
    )


fixed_split_decode_ref = fixed_split_decode
