"""LeanAttention decode kernel — Pallas/TPU stream-K implementation.

TPU adaptation of paper Algorithms 1+2. The grid is ``(G, T)``:

  * axis 0 (``G`` workers) is declared *parallel* — on hardware these are the
    units the Megacore/multi-chip runtime may distribute; every worker gets
    exactly ``T = ceil(total_tiles / G)`` LeanTile iterations (the stream-K
    equalized load, paper Eq. 2);
  * axis 1 (``T`` iterations per worker) is *arbitrary* (sequential): the
    online-softmax accumulation of Algorithm 1 runs in VMEM scratch across
    these steps, crossing (batch, head) segment boundaries as the schedule
    dictates.

Scalar-prefetch descriptors (built host-side by
:func:`repro.core.leantile.make_schedule`) drive the K/V BlockSpec index maps
— this is how a worker's iteration stream walks arbitrary tiles of arbitrary
segments with zero dynamic control flow on the data path.

Where the CUDA version uses a spin-lock "host block" fix-up inside one kernel
(GPU CTAs are co-resident; TPU grid steps are not), two execution modes are
offered:

  * **two-phase** (``lean_decode_partials`` + merge): each piece's un-scaled
    partial ``(o, m, l)`` goes to HBM and a second, cheap phase reduces per
    segment — XLA segment ops or the Pallas ``lean_merge`` kernel. The G
    axis stays ``parallel`` (Megacore/multi-core splittable).
  * **fused** (``lean_decode_fused``): ONE ``pallas_call`` whose flat grid
    appends the ``P`` merge iterations after the ``G*T`` partial iterations
    (descriptor-driven, same scalar-prefetch machinery). Partials live in a
    VMEM scratch ring — they never round-trip HBM, single-piece segments
    reduce in-register, and there is no second kernel launch. The grid is
    fully ``arbitrary`` (sequential per core), which is the right trade for
    the decode fast-path where the whole output is a few hundred KiB.

Both modes mask with *runtime* per-segment context lengths (a second
scalar-prefetch operand), so a schedule built over bucketed lengths
(:class:`repro.core.leantile.ScheduleCache`) computes exact attention for
the true ragged lengths — trailing over-bucketed tiles contribute identity
partials.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.leantile import LeanSchedule

NEG_INF = -1e30

# descriptor row layout in the packed (7, G*T) scalar-prefetch array.
# DESC_LEN carries the SCHEDULE's tile lengths (bucketed when the schedule
# came from a ScheduleCache) — kernels mask with the runtime ctx operand
# instead and never read this row; it stays packed for layout stability and
# host-side debugging only.
DESC_SEG, DESC_TILE, DESC_PIECE, DESC_FIRST, DESC_LAST, DESC_LEN, DESC_VALID = range(7)

# DESC_VALID doubles as the opcode row: 0 = padding, 1 = partial LeanTile
# iteration, 2 = merge iteration (fused kernel only).
OP_PAD, OP_PARTIAL, OP_MERGE = 0, 1, 2


def pack_descriptors(sched: LeanSchedule) -> np.ndarray:
    """Packed (7, G*T) int32 descriptors (memoized on the schedule)."""
    return sched.packed_descriptors()


def _online_softmax_tile(
    q_ref, k_ref, v_ref, acc_ref, m_acc_ref, l_acc_ref, vlen, scale,
    k_scale=None, v_scale=None,
):
    """One LeanTile online-softmax update (Algorithm 1 lines 20-25) against
    the VMEM accumulators; ``vlen`` masks the tile's invalid tail (and the
    whole tile when the runtime length ends before it).

    ``k_scale``/``v_scale`` are optional f32 dequant scalars for quantized
    (int8) KV tiles: the tile is widened to f32 and multiplied *before*
    entering the dot products, so the online-softmax accumulation — and
    therefore the merge numerics — is identical to the fp path. A scale of
    0 dequantizes to exact zeros (empty or scrubbed pages)."""
    q = q_ref[0].astype(jnp.float32)                       # (gq, d)
    k = k_ref[0].astype(jnp.float32)                       # (tile, d)
    v = v_ref[0].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale
    if v_scale is not None:
        v = v * v_scale

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (gq, tile)
    pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < vlen, s, NEG_INF)

    m_prev = m_acc_ref[...]                                # (gq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(pos < vlen, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_acc_ref[...] = alpha * l_acc_ref[...] + jnp.sum(
        p, axis=1, keepdims=True
    )
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_acc_ref[...] = m_new


def _lean_decode_kernel(
    desc_ref,      # (7, I) scalar-prefetch descriptors
    ctx_ref,       # (S,) scalar-prefetch runtime segment lengths
    q_ref,         # (1, gq, d)     current segment's query group
    k_ref,         # (1, tile, d)   current LeanTile of K
    v_ref,         # (1, tile, d)   current LeanTile of V
    *refs,         # [ks_ref (1,1), vs_ref (1,1)] when quantized, then:
                   # o_ref (1, gq, d)  partial un-scaled output (piece slot)
                   # m_ref (1, gq)     partial row-max
                   # l_ref (1, gq)     partial exp-sum
                   # acc_ref   VMEM (gq, d) f32
                   # m_acc_ref VMEM (gq, 1) f32
                   # l_acc_ref VMEM (gq, 1) f32
    scale: float,
    tile_size: int,
    tiles_per_worker: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, m_acc_ref, l_acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref, m_acc_ref, l_acc_ref = refs
        ks_ref = vs_ref = None
    g = pl.program_id(0)
    t = pl.program_id(1)
    i = g * tiles_per_worker + t

    first = desc_ref[DESC_FIRST, i]
    last = desc_ref[DESC_LAST, i]
    valid = desc_ref[DESC_VALID, i]

    @pl.when(valid == OP_PARTIAL)
    def _work():
        @pl.when(first == 1)
        def _reset():  # Algorithm 1 lines 8-9
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
            l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

        # runtime ragged length — the schedule may cover bucketed (longer)
        # lengths; tiles past the true length mask to identity
        vlen = jnp.clip(
            ctx_ref[desc_ref[DESC_SEG, i]]
            - desc_ref[DESC_TILE, i] * tile_size,
            0,
            tile_size,
        )
        _online_softmax_tile(
            q_ref, k_ref, v_ref, acc_ref, m_acc_ref, l_acc_ref, vlen, scale,
            k_scale=None if ks_ref is None else ks_ref[0, 0],
            v_scale=None if vs_ref is None else vs_ref[0, 0],
        )

        @pl.when(last == 1)
        def _flush():  # StorePartials (Algorithm 2 lines 20-22)
            o_ref[0] = acc_ref[...]
            m_ref[0] = m_acc_ref[..., 0]
            l_ref[0] = l_acc_ref[..., 0]


def lean_decode_partials(
    q_seg: jax.Array,          # (S_seg, gq, d)
    k_seg: jax.Array,          # dense: (S_seg, S_pad, d), S_pad % tile == 0
    v_seg: jax.Array,          #   paged: (num_pages * H_kv, page_size, d)
    seg_ctx: jax.Array,        # (S_seg,) int32 runtime context lengths
    sched: LeanSchedule,
    scale: float,
    interpret: bool = False,
    route: Optional[jax.Array] = None,   # paged: (G*T,) int32 pool rows
    k_scales: Optional[jax.Array] = None,  # quant: (rows, 1) f32 per-row scales
    v_scales: Optional[jax.Array] = None,
):
    """Phase 1: run the stream-K grid, return per-piece partials.

    Returns (o, m, l) with leading dim ``num_pieces`` (garbage row sliced
    off), f32. ``seg_ctx`` carries the true per-segment lengths; the
    schedule's (possibly bucketed) lengths only shape the tile walk.

    ``route`` switches K/V fetching to the paged layout: tiles come from
    flattened pool rows addressed by the routing operand instead of
    contiguous (segment, tile) slices. The kernel body — and therefore the
    fp op sequence — is identical either way.

    ``k_scales``/``v_scales`` (paged only) enable quantized KV: the pool
    rows hold int8 and each tile is dequantized in-kernel with its routed
    per-(page, head) f32 scale before the fp32 online softmax.
    """
    S_seg, gq, d = q_seg.shape
    tile = sched.tile_size
    G, T = sched.num_workers, sched.tiles_per_worker
    P = sched.num_pieces
    desc = jnp.asarray(pack_descriptors(sched))
    paged = route is not None
    quant = k_scales is not None
    if quant and not paged:
        raise ValueError("quantized KV requires the paged (route) layout")

    # index maps take (*grid, *prefetch_refs); trailing *_ absorbs the
    # extra routing operand in paged mode
    def q_map(g, t, desc, *_):
        i = g * T + t
        # padded iters clamp to segment 0 (they do no work)
        return (
            jnp.where(desc[DESC_VALID, i] == OP_PARTIAL, desc[DESC_SEG, i], 0),
            0,
            0,
        )

    def kv_map_dense(g, t, desc, *_):
        i = g * T + t
        ok = desc[DESC_VALID, i] == OP_PARTIAL
        return (
            jnp.where(ok, desc[DESC_SEG, i], 0),
            jnp.where(ok, desc[DESC_TILE, i], 0),
            0,
        )

    def kv_map_paged(g, t, desc, ctx, route):
        return (route[g * T + t], 0, 0)

    kv_map = kv_map_paged if paged else kv_map_dense

    def scale_map(g, t, desc, ctx, route):
        return (route[g * T + t], 0)

    def out_map(g, t, desc, *_):
        return (desc[DESC_PIECE, g * T + t], 0, 0)

    def stat_map(g, t, desc, *_):
        return (desc[DESC_PIECE, g * T + t], 0)

    in_specs = [
        pl.BlockSpec((1, gq, d), q_map),
        pl.BlockSpec((1, tile, d), kv_map),
        pl.BlockSpec((1, tile, d), kv_map),
    ]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if paged else 2,
        grid=(G, T),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, gq, d), out_map),
            pl.BlockSpec((1, gq), stat_map),
            pl.BlockSpec((1, gq), stat_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_partial_kernel if paged else _lean_decode_kernel,
        scale=scale, tile_size=tile, tiles_per_worker=T, quantized=quant,
    )
    out_shapes = [
        jax.ShapeDtypeStruct((P + 1, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((P + 1, gq), jnp.float32),
        jax.ShapeDtypeStruct((P + 1, gq), jnp.float32),
    ]
    operands = (desc, seg_ctx.astype(jnp.int32))
    if paged:
        operands += (route.astype(jnp.int32),)
    inputs = (q_seg, k_seg, v_seg)
    if quant:
        inputs += (
            k_scales.astype(jnp.float32), v_scales.astype(jnp.float32),
        )
    o_p, m_p, l_p = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands, *inputs)
    return o_p[:P], m_p[:P], l_p[:P]


def _lean_decode_fused_kernel(
    desc_ref,      # (7, G*T + P) scalar-prefetch descriptors (+merge rows)
    ctx_ref,       # (S,) scalar-prefetch runtime segment lengths
    q_ref,         # (1, gq, d)
    k_ref,         # (1, tile, d)
    v_ref,         # (1, tile, d)
    *refs,         # [ks_ref (1,1), vs_ref (1,1)] when quantized, then:
                   # o_ref (S, gq, d)  final outputs — VMEM-resident
                   # lse_ref (S, gq)   final logsumexp
                   # acc_ref   VMEM (gq, d) f32  shared partial/merge acc
                   # m_acc_ref VMEM (gq, 1) f32
                   # l_acc_ref VMEM (gq, 1) f32
                   # po_ref VMEM (P+1, gq, d) f32  piece partials (VMEM only)
                   # pm_ref VMEM (P+1, gq) f32
                   # pl_ref VMEM (P+1, gq) f32
    scale: float,
    tile_size: int,
    quantized: bool = False,
):
    if quantized:
        (ks_ref, vs_ref, o_ref, lse_ref, acc_ref, m_acc_ref, l_acc_ref,
         po_ref, pm_ref, pl_ref) = refs
    else:
        (o_ref, lse_ref, acc_ref, m_acc_ref, l_acc_ref,
         po_ref, pm_ref, pl_ref) = refs
        ks_ref = vs_ref = None
    i = pl.program_id(0)
    op = desc_ref[DESC_VALID, i]
    seg = desc_ref[DESC_SEG, i]
    piece = desc_ref[DESC_PIECE, i]
    first = desc_ref[DESC_FIRST, i]
    last = desc_ref[DESC_LAST, i]

    @pl.when(op == OP_PARTIAL)
    def _partial():
        @pl.when(first == 1)
        def _reset():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
            l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

        vlen = jnp.clip(
            ctx_ref[seg] - desc_ref[DESC_TILE, i] * tile_size, 0, tile_size
        )
        _online_softmax_tile(
            q_ref, k_ref, v_ref, acc_ref, m_acc_ref, l_acc_ref, vlen, scale,
            k_scale=None if ks_ref is None else ks_ref[0, 0],
            v_scale=None if vs_ref is None else vs_ref[0, 0],
        )

        @pl.when(last == 1)
        def _flush():  # StorePartials — into VMEM scratch, not HBM
            po_ref[pl.ds(piece, 1)] = acc_ref[...][None]
            pm_ref[pl.ds(piece, 1)] = m_acc_ref[..., 0][None]
            pl_ref[pl.ds(piece, 1)] = l_acc_ref[..., 0][None]

    @pl.when(op == OP_MERGE)
    def _merge():  # Algorithm 2 reduction, re-using the same accumulators
        @pl.when(first == 1)
        def _reset():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
            l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

        m_piece = pm_ref[pl.ds(piece, 1)][0][:, None]       # (gq, 1)
        l_piece = pl_ref[pl.ds(piece, 1)][0][:, None]
        o_piece = po_ref[pl.ds(piece, 1)][0]                # (gq, d)
        m_new = jnp.maximum(m_acc_ref[...], m_piece)
        a_old = jnp.exp(m_acc_ref[...] - m_new)
        a_new = jnp.exp(m_piece - m_new)
        l_acc_ref[...] = a_old * l_acc_ref[...] + a_new * l_piece
        acc_ref[...] = a_old * acc_ref[...] + a_new * o_piece
        m_acc_ref[...] = m_new

        @pl.when(last == 1)
        def _final():
            o_ref[pl.ds(seg, 1)] = (acc_ref[...] / l_acc_ref[...])[None]
            lse_ref[pl.ds(seg, 1)] = (
                m_acc_ref[...] + jnp.log(l_acc_ref[...])
            )[None, :, 0]


def fused_vmem_bytes(
    sched: LeanSchedule, gq: int, d: int, kv_elem_bytes: int = 4
) -> int:
    """Rough VMEM footprint of the fused kernel's resident state: f32 piece
    partials + whole-output block + a K and a V tile at the *cache dtype*
    width (``kv_elem_bytes``: 4 f32, 2 bf16, 1 int8/fp8 — a hardcoded 4
    here over-triggered the two-phase fallback for narrow KV). Used to
    gate the fused path (fall back to two-phase when a schedule would blow
    the budget)."""
    P, S = sched.num_pieces, sched.num_segments
    per_row = gq * (d + 2)
    return (
        4 * ((P + 1) * per_row + S * gq * (d + 1))
        + kv_elem_bytes * sched.tile_size * d * 2
    )


def lean_decode_fused(
    q_seg: jax.Array,          # (S_seg, gq, d)
    k_seg: jax.Array,          # dense: (S_seg, S_pad, d), S_pad % tile == 0
    v_seg: jax.Array,          #   paged: (num_pages * H_kv, page_size, d)
    seg_ctx: jax.Array,        # (S_seg,) int32 runtime context lengths
    sched: LeanSchedule,
    scale: float,
    interpret: bool = False,
    route: Optional[jax.Array] = None,   # paged: (G*T + P,) int32 pool rows
    k_scales: Optional[jax.Array] = None,  # quant: (rows, 1) f32 per-row scales
    v_scales: Optional[jax.Array] = None,
):
    """Fused stream-K decode: ONE ``pallas_call`` for partials AND merge.

    The flat grid runs the ``G*T`` LeanTile iterations followed by ``P``
    descriptor-driven merge iterations; per-piece ``(o, m, l)`` stay in a
    VMEM scratch ring the whole time. Returns (o (S, gq, d) f32,
    lse (S, gq) f32).

    The grid is sequential (``arbitrary``) — worker parallelism trades for
    zero HBM partial traffic and a single launch, the winning trade for
    decode-sized outputs. ``ops.lean_decode`` falls back to the two-phase
    path when :func:`fused_vmem_bytes` exceeds its budget.

    ``route`` switches K/V fetching to the paged pool-row layout (see
    :func:`lean_decode_partials`); merge iterations carry null routes.
    ``k_scales``/``v_scales`` (paged only) enable int8 KV with in-kernel
    per-(page, head) dequant — merge iterations route the scales to row 0
    along with the tiles, where they are never read.
    """
    S_seg, gq, d = q_seg.shape
    tile = sched.tile_size
    G, T = sched.num_workers, sched.tiles_per_worker
    P = sched.num_pieces
    desc = jnp.asarray(sched.fused_descriptors())
    N = G * T + P
    paged = route is not None
    quant = k_scales is not None
    if quant and not paged:
        raise ValueError("quantized KV requires the paged (route) layout")

    def q_map(i, desc, *_):
        return (
            jnp.where(desc[DESC_VALID, i] == OP_PAD, 0, desc[DESC_SEG, i]),
            0,
            0,
        )

    def kv_map_dense(i, desc, *_):
        ok = desc[DESC_VALID, i] == OP_PARTIAL
        return (
            jnp.where(ok, desc[DESC_SEG, i], 0),
            jnp.where(ok, desc[DESC_TILE, i], 0),
            0,
        )

    def kv_map_paged(i, desc, ctx, route):
        return (route[i], 0, 0)

    kv_map = kv_map_paged if paged else kv_map_dense

    def scale_map(i, desc, ctx, route):
        return (route[i], 0)

    in_specs = [
        pl.BlockSpec((1, gq, d), q_map),
        pl.BlockSpec((1, tile, d), kv_map),
        pl.BlockSpec((1, tile, d), kv_map),
    ]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if paged else 2,
        grid=(N,),
        in_specs=in_specs,
        out_specs=[
            # whole-output blocks: the index maps are constant, so the
            # buffers stay VMEM-resident across the grid and flush to HBM
            # exactly once at the end — no revisit hazards
            pl.BlockSpec((S_seg, gq, d), lambda i, *_: (0, 0, 0)),
            pl.BlockSpec((S_seg, gq), lambda i, *_: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((P + 1, gq, d), jnp.float32),
            pltpu.VMEM((P + 1, gq), jnp.float32),
            pltpu.VMEM((P + 1, gq), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_fused_kernel if paged else _lean_decode_fused_kernel,
        scale=scale, tile_size=tile, quantized=quant,
    )
    out_shapes = [
        jax.ShapeDtypeStruct((S_seg, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((S_seg, gq), jnp.float32),
    ]
    operands = (desc, seg_ctx.astype(jnp.int32))
    if paged:
        operands += (route.astype(jnp.int32),)
    inputs = (q_seg, k_seg, v_seg)
    if quant:
        inputs += (
            k_scales.astype(jnp.float32), v_scales.astype(jnp.float32),
        )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(*operands, *inputs)
    return o, lse


# ------------------------------------------------------------------ paged
# Page-table execution of the same stream-K schedules: K/V arrive as a
# global page pool flattened to (num_pages * H_kv, page_size, d) and a third
# scalar-prefetch operand carries, per grid iteration, the flattened pool
# row ``page * H_kv + head`` to fetch (built in kernels.ops from
# ``LeanSchedule.iter_kv_meta`` + the runtime page table). The kernel BODIES
# are the dense ones unchanged — only the K/V BlockSpec index maps differ —
# so paged and dense execution run the identical fp op sequence and produce
# bit-identical outputs on identical inputs. Invalid/merge iterations route
# to row 0 (the null page), whose contents are always masked.


def _paged_partial_kernel(desc_ref, ctx_ref, route_ref, *refs, **kw):
    _lean_decode_kernel(desc_ref, ctx_ref, *refs, **kw)


def _paged_fused_kernel(desc_ref, ctx_ref, route_ref, *refs, **kw):
    _lean_decode_fused_kernel(desc_ref, ctx_ref, *refs, **kw)


def lean_decode_paged_partials(
    q_seg: jax.Array,          # (S_seg, gq, d)
    k_rows: jax.Array,         # (num_pages * H_kv, page_size, d) pool rows
    v_rows: jax.Array,
    seg_ctx: jax.Array,        # (S_seg,) int32 runtime context lengths
    route: jax.Array,          # (G*T,) int32 pool row per iteration
    sched: LeanSchedule,
    scale: float,
    interpret: bool = False,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
):
    """Phase 1 of the paged path: :func:`lean_decode_partials` with the
    routing operand. ``sched.tile_size`` must equal the pool's page size."""
    return lean_decode_partials(
        q_seg, k_rows, v_rows, seg_ctx, sched, scale,
        interpret=interpret, route=route,
        k_scales=k_scales, v_scales=v_scales,
    )


def lean_decode_paged_fused(
    q_seg: jax.Array,          # (S_seg, gq, d)
    k_rows: jax.Array,         # (num_pages * H_kv, page_size, d) pool rows
    v_rows: jax.Array,
    seg_ctx: jax.Array,        # (S_seg,) int32 runtime context lengths
    route: jax.Array,          # (G*T + P,) int32 pool row per iteration
    sched: LeanSchedule,
    scale: float,
    interpret: bool = False,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
):
    """Fused paged stream-K decode: :func:`lean_decode_fused` with the
    routing operand."""
    return lean_decode_fused(
        q_seg, k_rows, v_rows, seg_ctx, sched, scale,
        interpret=interpret, route=route,
        k_scales=k_scales, v_scales=v_scales,
    )


# ---------------------------------------------------------------- cascade
# Fused cascade decode: prefix pass + suffix pass + segment merge in ONE
# descriptor-driven flat grid. The combined grid runs the prefix phase's
# partial iterations (stacked member queries, shared pages walked once per
# grouped pass), then the suffix phase's (per-sequence private tails), then
# the merge iterations — partials for BOTH phases stay in one VMEM scratch
# ring and never round-trip HBM, exactly like ``lean_decode_fused``.
#
# Descriptor semantics are op-dependent (the array is built by
# ``repro.core.leantile.cascade_fused_descriptors`` and arrives as a
# RUNTIME operand — only its shape is schedule-static, so regroupings with
# equal geometry replay one trace):
#   OP_PARTIAL: SEG = combined q-stack segment (prefix segments first,
#     suffix segments after), TILE = kv tile, PIECE = combined piece row;
#   OP_MERGE:   SEG = target *output* segment (b * H_kv + h, garbage = S),
#     TILE = member rank r — the iteration reduces partial rows
#     [r*g, (r+1)*g) of PIECE into the target's (g, d) accumulator.


def _lean_cascade_fused_kernel(
    desc_ref,      # (7, N) scalar-prefetch descriptors (runtime values)
    ctx_ref,       # (SEG_tot,) runtime lengths: pass lens ⊗ H_kv, suffix lens
    route_ref,     # (N,) pool-row routing (consumed by the index maps)
    q_ref,         # (1, qmax, d)   current segment's stacked query block
    k_ref,         # (1, tile, d)
    v_ref,         # (1, tile, d)
    *refs,         # [ks_ref (1,1), vs_ref (1,1)] when quantized, then:
                   # o_ref (S + 1, g, d)  final outputs (+ garbage row), VMEM
                   # lse_ref (S + 1, g)
                   # acc_ref   VMEM (qmax, d) f32  partial-phase accumulators
                   # m_acc_ref VMEM (qmax, 1) f32
                   # l_acc_ref VMEM (qmax, 1) f32
                   # g_acc_ref VMEM (g, d) f32     merge-phase accumulators
                   # g_m_ref   VMEM (g, 1) f32
                   # g_l_ref   VMEM (g, 1) f32
                   # po_ref VMEM (P_tot + 1, qmax, d) f32  piece partials
                   # pm_ref VMEM (P_tot + 1, qmax) f32
                   # pl_ref VMEM (P_tot + 1, qmax) f32
    scale: float,
    tile_size: int,
    gq: int,
    quantized: bool = False,
):
    if quantized:
        (ks_ref, vs_ref, o_ref, lse_ref, acc_ref, m_acc_ref, l_acc_ref,
         g_acc_ref, g_m_ref, g_l_ref, po_ref, pm_ref, pl_ref) = refs
    else:
        (o_ref, lse_ref, acc_ref, m_acc_ref, l_acc_ref,
         g_acc_ref, g_m_ref, g_l_ref, po_ref, pm_ref, pl_ref) = refs
        ks_ref = vs_ref = None
    i = pl.program_id(0)
    op = desc_ref[DESC_VALID, i]
    seg = desc_ref[DESC_SEG, i]
    piece = desc_ref[DESC_PIECE, i]
    first = desc_ref[DESC_FIRST, i]
    last = desc_ref[DESC_LAST, i]

    @pl.when(op == OP_PARTIAL)
    def _partial():
        @pl.when(first == 1)
        def _reset():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
            l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

        vlen = jnp.clip(
            ctx_ref[seg] - desc_ref[DESC_TILE, i] * tile_size, 0, tile_size
        )
        _online_softmax_tile(
            q_ref, k_ref, v_ref, acc_ref, m_acc_ref, l_acc_ref, vlen, scale,
            k_scale=None if ks_ref is None else ks_ref[0, 0],
            v_scale=None if vs_ref is None else vs_ref[0, 0],
        )

        @pl.when(last == 1)
        def _flush():
            po_ref[pl.ds(piece, 1)] = acc_ref[...][None]
            pm_ref[pl.ds(piece, 1)] = m_acc_ref[..., 0][None]
            pl_ref[pl.ds(piece, 1)] = l_acc_ref[..., 0][None]

    @pl.when(op == OP_MERGE)
    def _merge():
        @pl.when(first == 1)
        def _reset():
            g_acc_ref[...] = jnp.zeros_like(g_acc_ref)
            g_m_ref[...] = jnp.full_like(g_m_ref, NEG_INF)
            g_l_ref[...] = jnp.zeros_like(g_l_ref)

        off = desc_ref[DESC_TILE, i] * gq      # member rank -> row offset
        o_row = po_ref[pl.ds(piece, 1)][0]     # (qmax, d)
        m_row = pm_ref[pl.ds(piece, 1)][0]     # (qmax,)
        l_row = pl_ref[pl.ds(piece, 1)][0]
        o_piece = jax.lax.dynamic_slice_in_dim(o_row, off, gq, axis=0)
        m_piece = jax.lax.dynamic_slice_in_dim(m_row, off, gq, axis=0)[:, None]
        l_piece = jax.lax.dynamic_slice_in_dim(l_row, off, gq, axis=0)[:, None]
        m_new = jnp.maximum(g_m_ref[...], m_piece)
        a_old = jnp.exp(g_m_ref[...] - m_new)
        a_new = jnp.exp(m_piece - m_new)
        g_l_ref[...] = a_old * g_l_ref[...] + a_new * l_piece
        g_acc_ref[...] = a_old * g_acc_ref[...] + a_new * o_piece
        g_m_ref[...] = m_new

        @pl.when(last == 1)
        def _final():
            o_ref[pl.ds(seg, 1)] = (g_acc_ref[...] / g_l_ref[...])[None]
            lse_ref[pl.ds(seg, 1)] = (
                g_m_ref[...] + jnp.log(g_l_ref[...])
            )[None, :, 0]


def cascade_fused_vmem_bytes(
    csched, gq: int, d: int, kv_elem_bytes: int = 4
) -> int:
    """Rough VMEM footprint of the fused cascade kernel's resident state:
    the f32 combined piece-partial ring, the whole-output block, both
    accumulator sets, and a K + V tile at the cache dtype width
    (``kv_elem_bytes`` — see :func:`fused_vmem_bytes`). Gates the fused
    path — schedules above the budget fall back to the two-call cascade."""
    qmax = csched.group_size * gq
    Ptot = csched.num_pieces_total
    S = csched.batch * csched.num_kv_heads
    return 4 * (
        (Ptot + 1) * qmax * (d + 2)
        + (S + 1) * gq * (d + 1)
        + qmax * (d + 2)
        + gq * (d + 2)
        + qmax * d
    ) + kv_elem_bytes * 2 * csched.tile_size * d


def lean_cascade_fused(
    q_stack: jax.Array,        # (SEG_tot, qmax, d) prefix then suffix blocks
    k_rows: jax.Array,         # (num_pages * H_kv, page_size, d) pool rows
    v_rows: jax.Array,
    ctx_all: jax.Array,        # (SEG_tot,) int32 runtime per-segment lengths
    route: jax.Array,          # (N,) int32 pool row per grid iteration
    desc: jax.Array,           # (7, N) int32 fused cascade descriptors
    csched,
    scale: float,
    gq: int,
    interpret: bool = False,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
):
    """Fused cascade decode: ONE ``pallas_call`` for the grouped prefix
    pass, the per-sequence suffix pass, AND the merge. Returns
    ``(o (S, g, d) f32, lse (S, g) f32)`` with the garbage row sliced off.

    All operands — including the descriptors — are runtime arrays; the
    only static inputs are the schedule-derived shapes, so every grouping
    with the same :class:`~repro.core.leantile.CascadeSchedule` geometry
    replays this trace. ``k_scales``/``v_scales`` enable int8 pool rows
    with in-kernel per-(page, head) dequant."""
    SEG_tot, qmax, d = q_stack.shape
    tile = csched.tile_size
    N = csched.fused_grid_iters
    Ptot = csched.num_pieces_total
    S = csched.batch * csched.num_kv_heads
    quant = k_scales is not None

    def q_map(i, desc, *_):
        ok = desc[DESC_VALID, i] == OP_PARTIAL
        return (jnp.where(ok, desc[DESC_SEG, i], 0), 0, 0)

    def kv_map(i, desc, ctx, route):
        return (route[i], 0, 0)

    def scale_map(i, desc, ctx, route):
        return (route[i], 0)

    in_specs = [
        pl.BlockSpec((1, qmax, d), q_map),
        pl.BlockSpec((1, tile, d), kv_map),
        pl.BlockSpec((1, tile, d), kv_map),
    ]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((S + 1, gq, d), lambda i, *_: (0, 0, 0)),
            pl.BlockSpec((S + 1, gq), lambda i, *_: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qmax, d), jnp.float32),
            pltpu.VMEM((qmax, 1), jnp.float32),
            pltpu.VMEM((qmax, 1), jnp.float32),
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((Ptot + 1, qmax, d), jnp.float32),
            pltpu.VMEM((Ptot + 1, qmax), jnp.float32),
            pltpu.VMEM((Ptot + 1, qmax), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _lean_cascade_fused_kernel, scale=scale, tile_size=tile, gq=gq,
        quantized=quant,
    )
    out_shapes = [
        jax.ShapeDtypeStruct((S + 1, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((S + 1, gq), jnp.float32),
    ]
    inputs = (q_stack, k_rows, v_rows)
    if quant:
        inputs += (
            k_scales.astype(jnp.float32), v_scales.astype(jnp.float32),
        )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(
        desc.astype(jnp.int32), ctx_all.astype(jnp.int32),
        route.astype(jnp.int32), *inputs,
    )
    return o[:S], lse[:S]


def _lean_merge_kernel(
    meta_ref,      # (2, S) scalar prefetch: piece start / piece count
    o_p_ref,       # (1, gq, d)  one piece's partial o (revisited per j)
    m_p_ref,       # (1, gq)
    l_p_ref,       # (1, gq)
    o_ref,         # (1, gq, d)  final output for this segment
    l_out_ref,     # (1, gq)     logsumexp (for paged/backward use)
    acc_ref,       # VMEM (gq, d) f32
    m_acc_ref,     # VMEM (gq, 1)
    l_acc_ref,     # VMEM (gq, 1)
):
    s = pl.program_id(0)
    j = pl.program_id(1)
    cnt = meta_ref[1, s]

    @pl.when(j == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
        l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

    @pl.when(j < cnt)
    def _merge():  # Algorithm 2 lines 29-35: softmax re-scaling reduction
        m_piece = m_p_ref[0][:, None]
        m_new = jnp.maximum(m_acc_ref[...], m_piece)
        a_old = jnp.exp(m_acc_ref[...] - m_new)
        a_new = jnp.exp(m_piece - m_new)
        l_acc_ref[...] = a_old * l_acc_ref[...] + a_new * l_p_ref[0][:, None]
        acc_ref[...] = a_old * acc_ref[...] + a_new * o_p_ref[0].astype(
            jnp.float32
        )
        m_acc_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():  # Algorithm 2 lines 38-39
        o_ref[0] = acc_ref[...] / l_acc_ref[...]
        l_out_ref[0] = (m_acc_ref[...] + jnp.log(l_acc_ref[...]))[:, 0]


def lean_merge_pallas(
    o_p: jax.Array,      # (P, gq, d) f32 partials
    m_p: jax.Array,      # (P, gq)
    l_p: jax.Array,      # (P, gq)
    sched: LeanSchedule,
    interpret: bool = False,
):
    """Phase 2 as a Pallas kernel: per-segment reduction of pieces.

    Pieces are contiguous per segment (schedule invariant), so segment s owns
    piece rows [start[s], start[s]+cnt[s]). Grid (S, Pmax) revisits the
    output block while walking piece rows via scalar-prefetched offsets.
    """
    P, gq, d = o_p.shape
    S = sched.num_segments
    starts, cnts = sched.piece_ranges()
    pmax = max(1, int(cnts.max(initial=1)))
    meta = jnp.asarray(np.stack([starts, cnts]).astype(np.int32))

    def piece_map(s, j, meta):
        row = meta[0, s] + jnp.minimum(j, meta[1, s] - 1)
        return (jnp.clip(row, 0, P - 1), 0, 0)

    def piece_stat_map(s, j, meta):
        row = meta[0, s] + jnp.minimum(j, meta[1, s] - 1)
        return (jnp.clip(row, 0, P - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S, pmax),
        in_specs=[
            pl.BlockSpec((1, gq, d), piece_map),
            pl.BlockSpec((1, gq), piece_stat_map),
            pl.BlockSpec((1, gq), piece_stat_map),
        ],
        out_specs=[
            pl.BlockSpec((1, gq, d), lambda s, j, meta: (s, 0, 0)),
            pl.BlockSpec((1, gq), lambda s, j, meta: (s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((S, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((S, gq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        _lean_merge_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(meta, o_p, m_p, l_p)
    return o, lse
