"""LeanAttention decode kernel — Pallas/TPU stream-K implementation.

TPU adaptation of paper Algorithms 1+2. The grid is ``(G, T)``:

  * axis 0 (``G`` workers) is declared *parallel* — on hardware these are the
    units the Megacore/multi-chip runtime may distribute; every worker gets
    exactly ``T = ceil(total_tiles / G)`` LeanTile iterations (the stream-K
    equalized load, paper Eq. 2);
  * axis 1 (``T`` iterations per worker) is *arbitrary* (sequential): the
    online-softmax accumulation of Algorithm 1 runs in VMEM scratch across
    these steps, crossing (batch, head) segment boundaries as the schedule
    dictates.

Scalar-prefetch descriptors (built host-side by
:func:`repro.core.leantile.make_schedule`) drive the K/V BlockSpec index maps
— this is how a worker's iteration stream walks arbitrary tiles of arbitrary
segments with zero dynamic control flow on the data path.

Where the CUDA version uses a spin-lock "host block" fix-up inside one kernel
(GPU CTAs are co-resident; TPU grid steps are not), we emit each piece's
un-scaled partial ``(o, m, l)`` to HBM and reduce per segment in a second,
cheap phase (see ``ops.lean_decode``): the associative softmax re-scaling
merge of §IV-A, either as XLA segment ops or the Pallas ``lean_merge`` kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.leantile import LeanSchedule

NEG_INF = -1e30

# descriptor row layout in the packed (7, G*T) scalar-prefetch array
DESC_SEG, DESC_TILE, DESC_PIECE, DESC_FIRST, DESC_LAST, DESC_LEN, DESC_VALID = range(7)


def pack_descriptors(sched: LeanSchedule) -> np.ndarray:
    """Pack schedule descriptor arrays into one (7, G*T) int32 array."""
    return np.stack(
        [
            sched.iter_seg,
            sched.iter_tile,
            sched.iter_piece,
            sched.iter_first,
            sched.iter_last,
            sched.iter_len,
            sched.iter_valid,
        ]
    ).astype(np.int32)


def _lean_decode_kernel(
    desc_ref,      # (7, I) scalar-prefetch descriptors
    q_ref,         # (1, gq, d)     current segment's query group
    k_ref,         # (1, tile, d)   current LeanTile of K
    v_ref,         # (1, tile, d)   current LeanTile of V
    o_ref,         # (1, gq, d)     partial un-scaled output (piece slot)
    m_ref,         # (1, gq)        partial row-max
    l_ref,         # (1, gq)        partial exp-sum
    acc_ref,       # VMEM (gq, d) f32
    m_acc_ref,     # VMEM (gq, 1) f32
    l_acc_ref,     # VMEM (gq, 1) f32
    *,
    scale: float,
    tiles_per_worker: int,
):
    g = pl.program_id(0)
    t = pl.program_id(1)
    i = g * tiles_per_worker + t

    first = desc_ref[DESC_FIRST, i]
    last = desc_ref[DESC_LAST, i]
    vlen = desc_ref[DESC_LEN, i]
    valid = desc_ref[DESC_VALID, i]

    @pl.when(valid == 1)
    def _work():
        @pl.when(first == 1)
        def _reset():  # Algorithm 1 lines 8-9
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
            l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

        q = q_ref[0].astype(jnp.float32)                       # (gq, d)
        k = k_ref[0].astype(jnp.float32)                       # (tile, d)
        v = v_ref[0].astype(jnp.float32)

        # Algorithm 1 lines 20-25 (one LeanTile iteration)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                              # (gq, tile)
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < vlen, s, NEG_INF)

        m_prev = m_acc_ref[...]                                # (gq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < vlen, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_acc_ref[...] = alpha * l_acc_ref[...] + jnp.sum(
            p, axis=1, keepdims=True
        )
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_acc_ref[...] = m_new

        @pl.when(last == 1)
        def _flush():  # StorePartials (Algorithm 2 lines 20-22)
            o_ref[0] = acc_ref[...]
            m_ref[0] = m_acc_ref[..., 0]
            l_ref[0] = l_acc_ref[..., 0]


def lean_decode_partials(
    q_seg: jax.Array,          # (S_seg, gq, d)
    k_seg: jax.Array,          # (S_seg, S_pad, d), S_pad % tile == 0
    v_seg: jax.Array,
    sched: LeanSchedule,
    scale: float,
    interpret: bool = False,
):
    """Phase 1: run the stream-K grid, return per-piece partials.

    Returns (o, m, l) with leading dim ``num_pieces`` (garbage row sliced
    off), f32.
    """
    S_seg, gq, d = q_seg.shape
    tile = sched.tile_size
    G, T = sched.num_workers, sched.tiles_per_worker
    P = sched.num_pieces
    desc = jnp.asarray(pack_descriptors(sched))
    I = G * T

    def q_map(g, t, desc):
        i = g * T + t
        # padded iters clamp to segment 0 (they do no work)
        return (jnp.where(desc[DESC_VALID, i] == 1, desc[DESC_SEG, i], 0), 0, 0)

    def kv_map(g, t, desc):
        i = g * T + t
        ok = desc[DESC_VALID, i] == 1
        return (
            jnp.where(ok, desc[DESC_SEG, i], 0),
            jnp.where(ok, desc[DESC_TILE, i], 0),
            0,
        )

    def out_map(g, t, desc):
        return (desc[DESC_PIECE, g * T + t], 0, 0)

    def stat_map(g, t, desc):
        return (desc[DESC_PIECE, g * T + t], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, T),
        in_specs=[
            pl.BlockSpec((1, gq, d), q_map),
            pl.BlockSpec((1, tile, d), kv_map),
            pl.BlockSpec((1, tile, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, gq, d), out_map),
            pl.BlockSpec((1, gq), stat_map),
            pl.BlockSpec((1, gq), stat_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _lean_decode_kernel, scale=scale, tiles_per_worker=T
    )
    out_shapes = [
        jax.ShapeDtypeStruct((P + 1, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((P + 1, gq), jnp.float32),
        jax.ShapeDtypeStruct((P + 1, gq), jnp.float32),
    ]
    o_p, m_p, l_p = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(desc, q_seg, k_seg, v_seg)
    return o_p[:P], m_p[:P], l_p[:P]


def _lean_merge_kernel(
    meta_ref,      # (2, S) scalar prefetch: piece start / piece count
    o_p_ref,       # (1, gq, d)  one piece's partial o (revisited per j)
    m_p_ref,       # (1, gq)
    l_p_ref,       # (1, gq)
    o_ref,         # (1, gq, d)  final output for this segment
    l_out_ref,     # (1, gq)     logsumexp (for paged/backward use)
    acc_ref,       # VMEM (gq, d) f32
    m_acc_ref,     # VMEM (gq, 1)
    l_acc_ref,     # VMEM (gq, 1)
):
    s = pl.program_id(0)
    j = pl.program_id(1)
    cnt = meta_ref[1, s]

    @pl.when(j == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_acc_ref[...] = jnp.full_like(m_acc_ref, NEG_INF)
        l_acc_ref[...] = jnp.zeros_like(l_acc_ref)

    @pl.when(j < cnt)
    def _merge():  # Algorithm 2 lines 29-35: softmax re-scaling reduction
        m_piece = m_p_ref[0][:, None]
        m_new = jnp.maximum(m_acc_ref[...], m_piece)
        a_old = jnp.exp(m_acc_ref[...] - m_new)
        a_new = jnp.exp(m_piece - m_new)
        l_acc_ref[...] = a_old * l_acc_ref[...] + a_new * l_p_ref[0][:, None]
        acc_ref[...] = a_old * acc_ref[...] + a_new * o_p_ref[0].astype(
            jnp.float32
        )
        m_acc_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():  # Algorithm 2 lines 38-39
        o_ref[0] = acc_ref[...] / l_acc_ref[...]
        l_out_ref[0] = (m_acc_ref[...] + jnp.log(l_acc_ref[...]))[:, 0]


def lean_merge_pallas(
    o_p: jax.Array,      # (P, gq, d) f32 partials
    m_p: jax.Array,      # (P, gq)
    l_p: jax.Array,      # (P, gq)
    sched: LeanSchedule,
    interpret: bool = False,
):
    """Phase 2 as a Pallas kernel: per-segment reduction of pieces.

    Pieces are contiguous per segment (schedule invariant), so segment s owns
    piece rows [start[s], start[s]+cnt[s]). Grid (S, Pmax) revisits the
    output block while walking piece rows via scalar-prefetched offsets.
    """
    P, gq, d = o_p.shape
    S = sched.num_segments
    starts = np.searchsorted(sched.piece_seg, np.arange(S)).astype(np.int32)
    ends = np.searchsorted(
        sched.piece_seg, np.arange(S), side="right"
    ).astype(np.int32)
    cnts = ends - starts
    pmax = max(1, int(cnts.max(initial=1)))
    meta = jnp.asarray(np.stack([starts, cnts]).astype(np.int32))

    def piece_map(s, j, meta):
        row = meta[0, s] + jnp.minimum(j, meta[1, s] - 1)
        return (jnp.clip(row, 0, P - 1), 0, 0)

    def piece_stat_map(s, j, meta):
        row = meta[0, s] + jnp.minimum(j, meta[1, s] - 1)
        return (jnp.clip(row, 0, P - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S, pmax),
        in_specs=[
            pl.BlockSpec((1, gq, d), piece_map),
            pl.BlockSpec((1, gq), piece_stat_map),
            pl.BlockSpec((1, gq), piece_stat_map),
        ],
        out_specs=[
            pl.BlockSpec((1, gq, d), lambda s, j, meta: (s, 0, 0)),
            pl.BlockSpec((1, gq), lambda s, j, meta: (s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq, d), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
            pltpu.VMEM((gq, 1), jnp.float32),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((S, gq, d), jnp.float32),
        jax.ShapeDtypeStruct((S, gq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        _lean_merge_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(meta, o_p, m_p, l_p)
    return o, lse
