"""Pallas TPU kernels for the paper's compute hot-spot: decode attention.

lean_decode  — stream-K LeanAttention decode (the paper's contribution)
flash_decode — fixed-split FlashDecoding baseline
flash_prefill — FlashAttention-2 prefill (causal + sliding window, GQA)
ops.py jit'd wrappers; ref.py pure-jnp oracles.
Validated on CPU via interpret=True; TPU is the compile target.
"""
from .ops import (
    lean_decode,
    lean_decode_from_schedule,
    flash_decode,
    flash_prefill,
    default_num_workers,
)
