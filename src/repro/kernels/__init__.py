"""Pallas TPU kernels for the paper's compute hot-spot: decode attention.

lean_decode  — stream-K LeanAttention decode (the paper's contribution)
lean_prefill — stream-K chunked prefill (ragged chunk packs, paged KV)
flash_decode — fixed-split FlashDecoding baseline
flash_prefill — FlashAttention-2 prefill (causal + sliding window, GQA;
                dense and page-table-routed chunk variants)
ops.py jit'd wrappers; ref.py pure-jnp oracles.
Validated on CPU via interpret=True; TPU is the compile target.
"""
from .ops import (
    lean_decode,
    lean_decode_from_schedule,
    lean_prefill_chunks,
    flash_decode,
    flash_prefill,
    flash_prefill_paged,
    default_num_workers,
)
