"""Prefix-sharing benchmark: shared-system-prompt serving workload.

The fleet-shaped scenario the radix cache exists for: ``batch`` requests
that all start with one long shared prefix (a system prompt / few-shot
template) and differ only in a short private tail. Three engines run the
identical request stream:

  * ``baseline``   — paged lean engine, no sharing (every request
                     prefills and stores its own prefix copy);
  * ``prefix``     — radix cache on: matched prefixes skip prefill and
                     alias the cached pages (unshared schedule, bit-
                     identical decode);
  * ``cascade``    — radix cache + cascade decode: one grouped stream-K
                     pass over the shared prefix pages per tick, fused
                     with the suffix pass and the merge into a single
                     kernel.

A second, ``mixed_depth`` scenario stresses cascade v2's LCP grouping:
requests matching 1, 3, and 5 pages of ONE cached chain. The v1
identical-run grouping finds nothing to group there; LCP grouping forms
the trie passes. Reported: grouped-pass count, retrace count, and the
fused-vs-two-call tick speedup.

Reported per mode: decode ticks/sec and tokens/sec at steady state, mean
TTFT, KV pages in use, prefill tokens actually computed, and the radix
cache counters (hit rate, matched tokens, bytes saved). The section merges
into ``BENCH_decode_step.json`` next to the other serving benchmarks so
the perf trajectory stays one artifact per PR.

  PYTHONPATH=src python -m benchmarks.prefix_bench --ticks 12
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

PREFIX_PAGES = 8
PAGE = 16
TAIL = 16          # private tail length: keeps the whole measured window
                   # inside one suffix bucket (no mid-measurement retraces)
CHAIN_PAGES = 5    # mixed-depth scenario: one cached chain of 5 pages


def _build(cfg, params, *, prefix_cache, cascade, **ekw):
    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import Scheduler, SchedulerConfig

    from repro.serving.config import EngineConfig

    eng = DecodeEngine(cfg, params, config=EngineConfig.from_legacy(
        max_batch=8, cache_len=192, attn_backend="lean",
        num_workers=8, paged=True, page_size=PAGE,
        prefix_cache=prefix_cache, cascade=cascade,
        **({"cascade_stable_ticks": 1} if cascade else {}), **ekw,
    ))
    sched = Scheduler(eng, SchedulerConfig(
        chunk_size=32, prefill_pack=4, token_budget=256,
    ))
    return eng, sched


def _bucket_headroom(eng, cascade: bool) -> int:
    """Decode ticks until some active slot's schedule bucket changes.

    A bucket crossing re-keys the (cascade) schedule signature and costs
    one XLA retrace — microseconds of schedule work on hardware, ~seconds
    under CPU interpret — so the measured window must stay inside one
    bucket on every slot to report kernel throughput, not compile time.
    The cascade path buckets each slot's *suffix* (ctx minus its shared
    full pages), the plain paths the whole context.
    """
    from repro.core.leantile import bucket_length

    # the engine buckets each slot's suffix by its *kept-pass* coverage
    # (seq_prefix_len of the tick's binding), which can be shorter than
    # the slot's full shared run — e.g. a 5-page match whose deeper trie
    # level collapsed to a singleton groups (and shifts) at 3 pages
    bind = eng._casc_binding if cascade else None
    left = []
    for s in range(eng.max_batch):
        if eng.slot_req[s] is None:
            continue
        plen = int(bind.seq_prefix_len[s]) if bind is not None else 0
        n = int(eng.ctx_lens[s]) + 1 - plen
        left.append(bucket_length(n, eng.tile) - n)
    return min(left, default=1 << 30)


def _measure_decode(eng, n_ticks: int, cascade: bool):
    """Warm past bucket crossings + trace, then time ``n_ticks`` decode
    ticks; returns the sorted per-tick wall times."""
    guard = 0
    while _bucket_headroom(eng, cascade) < n_ticks + 2 and guard < 64:
        eng.decode_tick()
        guard += 1
    for _ in range(2):
        eng.decode_tick()
    ticks = []
    for _ in range(n_ticks):
        t0 = time.perf_counter()
        eng.decode_tick()
        ticks.append(time.perf_counter() - t0)
    ticks.sort()
    return ticks


def _run_mode(cfg, params, prompts, shared, *, prefix_cache, cascade,
              n_ticks):
    import numpy as np

    eng, sched = _build(cfg, params, prefix_cache=prefix_cache,
                        cascade=cascade)
    if prefix_cache:
        # seed the radix cache with one donor request (the "first user" —
        # its prefill is the one copy of the shared prompt anyone pays for)
        donor = sched.submit(np.concatenate([shared, [1]]), 1)  # noqa: F841
        sched.run_to_completion(max_steps=100)
    handles = [sched.submit(p, max_new_tokens=10_000) for p in prompts]
    while any(h.state.value != "decoding" for h in handles):
        sched.step()
    ttfts = [h.first_token_time - h.arrival_time for h in handles]
    pages_in_use = eng.pool.num_allocated
    ticks = _measure_decode(eng, n_ticks, cascade)
    # best-observed per-tick: the classic noise-robust estimator — host
    # load spikes and allocator hiccups only ever ADD time
    dt = ticks[0]
    eng.pool.check()
    if eng.prefix_cache is not None:
        eng.prefix_cache.check()
    return {
        "ticks_per_sec": 1.0 / dt,
        "tokens_per_sec": len(prompts) / dt,
        "tick_ms_min": ticks[0] * 1e3,
        "tick_ms_median": ticks[len(ticks) // 2] * 1e3,
        "tick_ms_max": ticks[-1] * 1e3,
        "ttft_mean_s": sum(ttfts) / len(ttfts),
        "kv_pages_in_use": int(pages_in_use),
        "prefill_tokens_computed": int(eng.stats.prefill_tokens),
        "prefix_matched_tokens": int(eng.stats.prefix_matched_tokens),
        "cascade_ticks": int(eng.stats.cascade_ticks),
        "cascade_fused_ticks": int(eng.stats.cascade_fused_ticks),
        "cow_copies": int(eng.stats.cow_copies),
        "prefix_cache": dict(eng.stats.prefix_cache),
        "pages_saved": int(eng.pool.pages_saved),
    }


def _run_mixed_mode(cfg, params, prompts, chain, *, grouping, fused,
                    n_ticks):
    """One mixed-depth engine run: seed the chain, admit the 1/3/5-page
    matchers, measure steady-state decode + cascade grouping counters."""
    import numpy as np

    eng, sched = _build(cfg, params, prefix_cache=True, cascade=True,
                        cascade_grouping=grouping, cascade_fused=fused)
    donor = sched.submit(np.concatenate([chain, [1]]), 1)  # noqa: F841
    sched.run_to_completion(max_steps=100)
    handles = [sched.submit(p, max_new_tokens=10_000) for p in prompts]
    while any(h.state.value != "decoding" for h in handles):
        sched.step()
    ticks = _measure_decode(eng, n_ticks, cascade=True)
    eng.pool.check()
    eng.prefix_cache.check()
    s = eng.stats
    return {
        "ticks_per_sec": 1.0 / ticks[0],
        "tick_ms_min": ticks[0] * 1e3,
        "tick_ms_median": ticks[len(ticks) // 2] * 1e3,
        "cascade_ticks": int(s.cascade_ticks),
        "cascade_fused_ticks": int(s.cascade_fused_ticks),
        "grouped_passes_total": int(s.cascade_grouped_passes),
        "grouped_passes_per_tick": (
            s.cascade_grouped_passes / s.cascade_ticks
            if s.cascade_ticks else 0.0
        ),
        "grouped_slots_per_tick": (
            s.cascade_grouped_slots / s.cascade_ticks
            if s.cascade_ticks else 0.0
        ),
        "levels_max": int(s.cascade_levels_max),
        "retraces": int(s.cascade_retraces),
        "stability_skips": int(s.cascade_stability_skips),
        "last_grouping": dict(s.cascade_last),
    }


def run_mixed_depth(cfg, params, n_ticks: int) -> dict:
    """Mixed-depth LCP scenario: requests matching 1, 3, and 5 pages of
    one cached chain. Compares LCP vs identical-run grouping (grouped
    passes, retraces) and fused vs two-call cascade execution (tick
    speedup)."""
    import numpy as np

    rng = np.random.default_rng(2)
    chain = rng.integers(0, cfg.vocab_size, CHAIN_PAGES * PAGE)
    prompts = [
        np.concatenate([chain[: d * PAGE],
                        rng.integers(0, cfg.vocab_size, TAIL)])
        for d in (1, 3, 5)
    ]
    section = {
        "workload": {
            "chain_pages": CHAIN_PAGES,
            "match_depths_pages": [1, 3, 5],
            "private_tail_tokens": TAIL,
            "page_size": PAGE,
            "ticks": n_ticks,
        },
        "lcp": _run_mixed_mode(
            cfg, params, prompts, chain, grouping="lcp", fused=True,
            n_ticks=n_ticks,
        ),
        "identical": _run_mixed_mode(
            cfg, params, prompts, chain, grouping="identical", fused=True,
            n_ticks=n_ticks,
        ),
        "lcp_two_call": _run_mixed_mode(
            cfg, params, prompts, chain, grouping="lcp", fused=False,
            n_ticks=n_ticks,
        ),
    }
    lcp, ident, two = (
        section["lcp"], section["identical"], section["lcp_two_call"]
    )
    section["headline"] = {
        # the acceptance claim: LCP groups mixed-depth matches the
        # identical-run grouping cannot see at all
        "grouped_passes_per_tick_lcp": lcp["grouped_passes_per_tick"],
        "grouped_passes_per_tick_identical":
            ident["grouped_passes_per_tick"],
        "lcp_beats_identical_grouping":
            lcp["grouped_passes_per_tick"]
            > ident["grouped_passes_per_tick"],
        "retraces_lcp": lcp["retraces"],
        "fused_over_two_call_speedup":
            lcp["ticks_per_sec"] / two["ticks_per_sec"],
        "multi_level_engaged": lcp["levels_max"] >= 2,
    }
    return section


def run_prefix(n_ticks: int = 12, out_path: str = "BENCH_decode_step.json",
               rows: list | None = None) -> dict:
    import jax

    jax.config.update("jax_platform_name", "cpu")
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import init_params

    cfg = get_smoke_config("mistral-nemo-12b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, PREFIX_PAGES * PAGE)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, TAIL)])
        for _ in range(8)
    ]

    section: dict = {"workload": {
        "batch": 8, "shared_prefix_tokens": int(PREFIX_PAGES * PAGE),
        "private_tail_tokens": TAIL, "page_size": PAGE,
        "ticks": n_ticks, "platform": "cpu-interpret",
    }}
    section["baseline"] = _run_mode(
        cfg, params, prompts, shared, prefix_cache=False, cascade=False,
        n_ticks=n_ticks,
    )
    section["prefix"] = _run_mode(
        cfg, params, prompts, shared, prefix_cache=True, cascade=False,
        n_ticks=n_ticks,
    )
    section["cascade"] = _run_mode(
        cfg, params, prompts, shared, prefix_cache=True, cascade=True,
        n_ticks=n_ticks,
    )
    base, pref, casc = (
        section["baseline"], section["prefix"], section["cascade"]
    )
    section["headline"] = {
        "kv_pages_prefix_vs_baseline":
            f"{pref['kv_pages_in_use']}/{base['kv_pages_in_use']}",
        "kv_pages_strictly_below_baseline":
            pref["kv_pages_in_use"] < base["kv_pages_in_use"]
            and casc["kv_pages_in_use"] < base["kv_pages_in_use"],
        "ttft_speedup_prefix": base["ttft_mean_s"] / pref["ttft_mean_s"],
        "decode_speedup_prefix":
            pref["ticks_per_sec"] / base["ticks_per_sec"],
        "decode_speedup_cascade":
            casc["ticks_per_sec"] / base["ticks_per_sec"],
        "prefill_tokens_skipped":
            base["prefill_tokens_computed"]
            - pref["prefill_tokens_computed"],
    }
    section["mixed_depth"] = run_mixed_depth(cfg, params, n_ticks)

    # merge into the shared benchmark artifact
    out = Path(out_path)
    doc = json.loads(out.read_text()) if out.exists() else {}
    doc["prefix"] = section
    out.write_text(json.dumps(doc, indent=1))

    if rows is not None:
        h = section["headline"]
        hm = section["mixed_depth"]["headline"]
        rows.append(("prefix_decode_speedup_cascade", 0.0,
                     h["decode_speedup_cascade"]))
        rows.append(("prefix_decode_speedup_aliased", 0.0,
                     h["decode_speedup_prefix"]))
        rows.append(("prefix_ttft_speedup", 0.0, h["ttft_speedup_prefix"]))
        rows.append(("prefix_kv_pages_saved", 0.0,
                     float(base["kv_pages_in_use"]
                           - pref["kv_pages_in_use"])))
        rows.append(("prefix_mixed_lcp_passes_per_tick", 0.0,
                     hm["grouped_passes_per_tick_lcp"]))
        rows.append(("prefix_mixed_fused_speedup", 0.0,
                     hm["fused_over_two_call_speedup"]))
    return section


def run(rows: list):
    run_prefix(rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=12)
    ap.add_argument("--out", default="BENCH_decode_step.json")
    args = ap.parse_args()
    s = run_prefix(args.ticks, args.out)
    print(json.dumps(s, indent=1))
    h = s["headline"]
    hm = s["mixed_depth"]["headline"]
    print(
        f"\nKV pages {s['prefix']['kv_pages_in_use']} (shared) vs "
        f"{s['baseline']['kv_pages_in_use']} (baseline); TTFT "
        f"{h['ttft_speedup_prefix']:.2f}x faster; decode "
        f"{h['decode_speedup_cascade']:.2f}x (cascade) / "
        f"{h['decode_speedup_prefix']:.2f}x (aliased) vs no sharing; "
        f"{h['prefill_tokens_skipped']} prefill tokens skipped"
    )
    print(
        f"mixed-depth 1/3/5: LCP {hm['grouped_passes_per_tick_lcp']:.1f} "
        f"grouped passes/tick vs identical "
        f"{hm['grouped_passes_per_tick_identical']:.1f}; "
        f"{hm['retraces_lcp']} retraces; fused vs two-call "
        f"{hm['fused_over_two_call_speedup']:.2f}x"
    )


if __name__ == "__main__":
    main()
