"""Paper Fig. 10 — ragged (heterogeneous-context) batching.

Heterogeneity = avg(ctx) / max(ctx) ("batch context ratio"). Fixed-split
must split every segment as if it were max-length (idle tail for short
ones); the lean schedule only assigns real tiles, so its advantage *grows*
as the batch gets more ragged — the paper's Fig. 10 trend.
"""
from __future__ import annotations

import numpy as np

from repro.core.leantile import default_tile_size
from .occupancy_model import A100, speedups


def make_ragged(batch: int, max_ctx: int, ratio: float, rng) -> list:
    """Batch with avg/max ~= ratio: one max-length row, rest geometric."""
    lens = [max_ctx]
    target_sum = ratio * max_ctx * batch
    rest = batch - 1
    if rest:
        remaining = max(target_sum - max_ctx, rest * 128.0)
        base = remaining / rest
        lens += [
            int(np.clip(rng.normal(base, base * 0.3), 128, max_ctx))
            for _ in range(rest)
        ]
    return lens


def run(rows: list):
    tile = default_tile_size(64)
    rng = np.random.default_rng(0)
    for batch in (4, 8, 16):
        for ratio in (1.0, 0.75, 0.5, 0.25):
            lens = make_ragged(batch, 131072, ratio, rng)
            s = speedups(lens, 32, tile, A100)
            rows.append(
                (
                    f"fig10_bs{batch}_ratio{int(ratio*100)}_la_vs_fd",
                    s["la"],
                    s["la_vs_fd"],
                )
            )
            rows.append(
                (
                    f"fig10_bs{batch}_ratio{int(ratio*100)}_occ_fd",
                    s["fd"],
                    s["occ_fd"],
                )
            )
