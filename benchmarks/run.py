"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. "derived" is the figure's metric
(speedup ratio, occupancy, timeshare, or error vs oracle, per row name).
The ``decode_step`` suite also appends an environment-fingerprinted
absolute-throughput record to the trajectory store (``--history``, see
:mod:`benchmarks.trajectory`) that the check_regression absolute gate
compares like-fingerprint runs against.

  python -m benchmarks.run            # all
  python -m benchmarks.run --only fig7,fig10
  python -m benchmarks.run --only decode_step --history ''   # no append
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument(
        "--history", default="BENCH_history.jsonl",
        help="perf-trajectory store the decode_step suite appends its "
             "fingerprinted absolute-throughput record to ('' disables)",
    )
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    history = args.history or None

    rows: list = []
    from . import (
        attention_bench, decode_step_bench, e2e_bench, prefix_bench,
        ragged_bench,
    )

    suites = {
        "decode_step": lambda: decode_step_bench.run(
            rows, history_path=history),
        "prefix": lambda: prefix_bench.run(rows),
        "fig7": lambda: attention_bench.fig7_context_sweep(rows),
        "fig7b": lambda: attention_bench.fig7b_heads_sweep(rows),
        "fig7c": lambda: attention_bench.fig7c_batch_sweep(rows),
        "fig8": lambda: attention_bench.fig8_h100(rows),
        "fig9": lambda: attention_bench.fig9_multi_gpu(rows),
        "claims": lambda: attention_bench.paper_claim_grid(rows),
        "cpu": lambda: attention_bench.cpu_wallclock_sanity(rows),
        "fig10": lambda: ragged_bench.run(rows),
        "fig12": lambda: e2e_bench.run(rows),
        "engine": lambda: e2e_bench.run_real_engine(rows),
    }
    for name, fn in suites.items():
        if only and not any(name.startswith(o) or o.startswith(name)
                            for o in only):
            continue
        fn()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived:.6g}")


if __name__ == "__main__":
    main()
