"""Paper Figs. 7/8/9 — LA vs FD vs FA2 across context, heads, batch.

Two layers of evidence per point:
  1. the analytic schedule model (occupancy_model.py) at the paper's device
     widths — reproduces the paper's speedup *curves*;
  2. CPU wall-clock of the actual jnp schedule executors on reduced shapes
     (exactness + direction sanity only; CPU time does not model SMs).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import fixed_split_decode, lean_decode_jnp, mha_decode_ref
from repro.core.leantile import default_tile_size, make_schedule

from .occupancy_model import A100, H100, A100x8, speedups


def fig7_context_sweep(rows: list):
    """A100, 32 heads, batch 4, d=64 (tile 256), ctx 1k..256k."""
    tile = default_tile_size(64)
    for ctx in (1024, 4096, 16384, 65536, 262144):
        s = speedups([ctx] * 4, 32, tile, A100)
        rows.append((f"fig7a_ctx{ctx//1024}k_la_vs_fd", s["la"], s["la_vs_fd"]))
        rows.append((f"fig7a_ctx{ctx//1024}k_occ_la", s["la"], s["occ_la"]))
        rows.append((f"fig7a_ctx{ctx//1024}k_occ_fd", s["fd"], s["occ_fd"]))


def fig7b_heads_sweep(rows: list):
    tile = default_tile_size(64)
    for h in (8, 16, 24, 32, 56, 128):
        s = speedups([262144] * 4, h, tile, A100)
        rows.append((f"fig7b_heads{h}_la_vs_fd", s["la"], s["la_vs_fd"]))


def fig7c_batch_sweep(rows: list):
    tile = default_tile_size(64)
    for b in (1, 2, 4, 8, 16, 32):
        s = speedups([65536] * b, 32, tile, A100)
        rows.append((f"fig7c_bs{b}_la_vs_fd", s["la"], s["la_vs_fd"]))


def fig8_h100(rows: list):
    tile = default_tile_size(64)
    for ctx in (4096, 16384, 65536):
        s = speedups([ctx] * 6, 48, tile, H100)
        rows.append((f"fig8_ctx{ctx//1024}k_la_vs_fd", s["la"], s["la_vs_fd"]))


def fig9_multi_gpu(rows: list):
    tile = default_tile_size(64)
    for ctx in (1024, 16384, 262144, 1048576):
        s = speedups([ctx] * 4, 256, tile, A100x8)
        rows.append((f"fig9_ctx{ctx//1024}k_la_vs_fd", s["la"], s["la_vs_fd"]))


def paper_claim_grid(rows: list):
    """Paper: >1000 samples, avg 1.73x over FD on A100 (max 2.18x)."""
    tile = default_tile_size(64)
    rng = np.random.default_rng(0)
    ratios = []
    for _ in range(1000):
        b = int(rng.choice([1, 2, 4, 8, 16]))
        h = int(rng.choice([8, 12, 16, 24, 32, 48, 56, 64, 96, 128]))
        ctx = int(rng.choice([1, 2, 4, 8, 16, 32, 64, 128, 256, 512])) * 1024
        ratios.append(speedups([ctx] * b, h, tile, A100)["la_vs_fd"])
    ratios = np.asarray(ratios)
    rows.append(("paper_claim_avg_la_vs_fd", 0.0, float(ratios.mean())))
    rows.append(("paper_claim_max_la_vs_fd", 0.0, float(ratios.max())))
    rows.append(("paper_claim_min_la_vs_fd", 0.0, float(ratios.min())))


def cpu_wallclock_sanity(rows: list):
    """Exactness + wall-clock of actual executors on a reduced problem."""
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, d = 2, 8, 4, 2048, 64
    q = jnp.asarray(rng.standard_normal((B, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)
    sched = make_schedule([S] * B, Hkv, 256, 16)

    fns = {
        "cpu_ref_oracle": jax.jit(lambda: mha_decode_ref(q, k, v)),
        "cpu_fixed_split": jax.jit(
            lambda: fixed_split_decode(q, k, v, num_splits=4)
        ),
        "cpu_lean_jnp": jax.jit(lambda: lean_decode_jnp(q, k, v, sched)),
    }
    ref = None
    for name, fn in fns.items():
        out = fn()
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn()
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 10 * 1e6
        if ref is None:
            ref = out
            err = 0.0
        else:
            err = float(jnp.max(jnp.abs(out - ref)))
        rows.append((name, us, err))


def run(rows: list):
    fig7_context_sweep(rows)
    fig7b_heads_sweep(rows)
    fig7c_batch_sweep(rows)
    fig8_h100(rows)
    fig9_multi_gpu(rows)
    paper_claim_grid(rows)
    cpu_wallclock_sanity(rows)
