"""Decode fast-path benchmark: jitted+cached engine vs the legacy per-tick
path, machine-readable across PRs.

Measures, on the CPU-interpret smoke config (the CI-reproducible proxy for
the launcher/host overhead the fast path removes):

  * ticks/sec of the fast path (schedule cache + whole-step jit + fused
    kernel) vs the legacy baseline (fresh schedule + unjitted outer step),
  * schedule-cache hit rate at steady state,
  * host-ms vs device-ms per tick (device = replaying the jitted step with
    fixed inputs; host = everything else the tick does).

Writes ``BENCH_decode_step.json`` (``--out``) so the perf trajectory is
diffable across PRs, and appends CSV rows for ``benchmarks.run``.

  PYTHONPATH=src python -m benchmarks.decode_step_bench --ticks 32
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _mk_engine(cfg, params, backend, **kw):
    from repro.serving.engine import DecodeEngine

    return DecodeEngine(
        cfg, params, max_batch=4, cache_len=64, attn_backend=backend,
        num_workers=8, **kw,
    )


def _feed(eng, cfg, n=6, seed=0):
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    for uid in range(n):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 8 + 5 * (uid % 3)),
            max_new_tokens=1_000_000,   # keep slots occupied: steady state
        ))


def _ticks_per_sec(eng, cfg, n_ticks, warmup=3):
    _feed(eng, cfg)
    for _ in range(warmup):
        eng.tick()
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        eng.tick()
    dt = time.perf_counter() - t0
    return n_ticks / dt, dt / n_ticks


def _device_ms_per_tick(eng, n_reps=8):
    """Replay the jitted kernel step with fixed inputs: pure device time
    (trace is warm, schedule cached)."""
    import jax
    import jax.numpy as jnp

    from repro.core.leantile import fixed_split_factor

    sched = eng._tick_schedule()
    tokens = jnp.asarray(eng.next_tokens)
    ctx = jnp.asarray(eng.ctx_lens, jnp.int32)
    num_splits = fixed_split_factor(
        int(sched.seg_len.max(initial=1)), sched.num_segments, eng.tile,
        eng.num_workers,
    )

    def step():
        logits, new_cache = eng._jit_kernel_step(
            eng.params, eng.cache, tokens, ctx,
            backend=eng.attn_backend, sched=sched, num_splits=num_splits,
            fused=eng.fused, interpret=eng.interpret,
        )
        eng.cache = new_cache
        return jax.block_until_ready(logits)

    step()                                   # warm
    t0 = time.perf_counter()
    for _ in range(n_reps):
        step()
    return (time.perf_counter() - t0) * 1e3 / n_reps


def run_decode_step(n_ticks: int = 24, out_path: str = "BENCH_decode_step.json",
                    rows: list | None = None) -> dict:
    import jax

    jax.config.update("jax_platform_name", "cpu")

    from repro.configs import get_smoke_config
    from repro.models import init_params

    cfg = get_smoke_config("mistral-nemo-12b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    result: dict = {"config": {
        "arch": "mistral-nemo-12b(smoke)", "max_batch": 4, "cache_len": 64,
        "num_workers": 8, "ticks": n_ticks, "platform": "cpu-interpret",
    }}

    # fast path (lean fused) — also collect host/device split
    eng_fast = _mk_engine(cfg, params, "lean", use_fast_path=True, fused=True)
    tps_fast, s_per_tick = _ticks_per_sec(eng_fast, cfg, n_ticks)
    dev_ms = _device_ms_per_tick(eng_fast)
    cache_stats = eng_fast.sched_cache.stats.as_dict()

    # legacy baseline (pre-PR behavior: per-tick schedule, unjitted step)
    eng_legacy = _mk_engine(cfg, params, "lean", use_fast_path=False)
    n_legacy = max(4, n_ticks // 4)          # it is slow; sample fewer ticks
    tps_legacy, _ = _ticks_per_sec(eng_legacy, cfg, n_legacy, warmup=1)

    # ref backend fast path for context (jnp attention, always jitted)
    eng_ref = _mk_engine(cfg, params, "ref", use_fast_path=True)
    tps_ref, _ = _ticks_per_sec(eng_ref, cfg, n_ticks)

    result["decode_step"] = {
        "ticks_per_sec_fast": tps_fast,
        "ticks_per_sec_legacy": tps_legacy,
        "ticks_per_sec_ref_backend": tps_ref,
        "speedup_vs_legacy": tps_fast / tps_legacy,
        "ms_per_tick_fast": s_per_tick * 1e3,
        "device_ms_per_tick": dev_ms,
        "host_ms_per_tick": max(0.0, s_per_tick * 1e3 - dev_ms),
        "schedule_cache": cache_stats,
    }
    Path(out_path).write_text(json.dumps(result, indent=1))
    if rows is not None:
        d = result["decode_step"]
        rows.append(("decode_step_fast_us_per_tick",
                     d["ms_per_tick_fast"] * 1e3, d["speedup_vs_legacy"]))
        rows.append(("decode_step_cache_hit_rate", 0.0,
                     cache_stats["hit_rate"]))
    return result


def run(rows: list):
    run_decode_step(rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--out", default="BENCH_decode_step.json")
    args = ap.parse_args()
    result = run_decode_step(args.ticks, args.out)
    d = result["decode_step"]
    print(json.dumps(result, indent=1))
    print(
        f"\nfast {d['ticks_per_sec_fast']:.2f} ticks/s vs legacy "
        f"{d['ticks_per_sec_legacy']:.2f} ticks/s "
        f"({d['speedup_vs_legacy']:.1f}x); cache hit rate "
        f"{d['schedule_cache']['hit_rate']:.2f}; "
        f"host {d['host_ms_per_tick']:.1f}ms + device "
        f"{d['device_ms_per_tick']:.1f}ms per tick"
    )


if __name__ == "__main__":
    main()
