"""Decode fast-path benchmark: jitted+cached engine vs the legacy per-tick
path, machine-readable across PRs.

Measures, on the CPU-interpret smoke config (the CI-reproducible proxy for
the launcher/host overhead the fast path removes):

  * ticks/sec of the fast path (schedule cache + whole-step jit + fused
    kernel) vs the legacy baseline (fresh schedule + unjitted outer step),
  * schedule-cache hit rate at steady state,
  * host-ms vs device-ms per tick (device = replaying the jitted step with
    fixed inputs; host = everything else the tick does).

Writes ``BENCH_decode_step.json`` (``--out``) so the perf trajectory is
diffable across PRs, and appends CSV rows for ``benchmarks.run``.

  PYTHONPATH=src python -m benchmarks.decode_step_bench --ticks 32
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _mk_engine(cfg, params, backend, **kw):
    from repro.serving.config import EngineConfig
    from repro.serving.engine import DecodeEngine

    # from_legacy maps the bench's flat knobs onto the typed nest, so every
    # section constructs engines through the new one-argument API
    return DecodeEngine(cfg, params, config=EngineConfig.from_legacy(
        max_batch=4, cache_len=64, attn_backend=backend, num_workers=8, **kw,
    ))


def _feed(eng, cfg, n=6, seed=0):
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    for uid in range(n):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 8 + 5 * (uid % 3)),
            max_new_tokens=1_000_000,   # keep slots occupied: steady state
        ))


def _ticks_per_sec(eng, cfg, n_ticks, warmup=3):
    _feed(eng, cfg)
    for _ in range(warmup):
        eng.tick()
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        eng.tick()
    dt = time.perf_counter() - t0
    return n_ticks / dt, dt / n_ticks


def _device_ms_per_tick(eng, n_reps=8):
    """Replay the jitted kernel step with fixed inputs: pure device time
    (trace is warm, schedule cached)."""
    import jax
    import jax.numpy as jnp

    from repro.core.leantile import fixed_split_factor

    sched = eng._tick_schedule()
    tokens = jnp.asarray(eng.next_tokens)
    ctx = jnp.asarray(eng.ctx_lens, jnp.int32)
    num_splits = fixed_split_factor(
        int(sched.seg_len.max(initial=1)), sched.num_segments, eng.tile,
        eng.num_workers,
    )

    def step():
        logits, new_cache = eng._jit_kernel_step(
            eng.params, eng.cache, tokens, ctx,
            backend=eng.attn_backend, sched=sched, num_splits=num_splits,
            fused=eng.fused, interpret=eng.interpret,
        )
        eng.cache = new_cache
        return jax.block_until_ready(logits)

    step()                                   # warm
    t0 = time.perf_counter()
    for _ in range(n_reps):
        step()
    return (time.perf_counter() - t0) * 1e3 / n_reps


def _attn_kv_bytes(eng) -> int:
    """Bytes held by global-attention KV state (dense slot rows, or the
    page pools in paged mode), including any quantization scale sidecars
    — they are real device footprint."""
    total = 0
    for (pattern, reps), st_c in zip(eng.cfg.stages, eng.cache):
        for kind, lc in zip(pattern, st_c):
            if kind == "attn":
                total += lc["k"].nbytes + lc["v"].nbytes
                for key in ("k_scale", "v_scale"):
                    if key in lc:
                        total += lc[key].nbytes
    return total


def _run_paged_section(cfg, params, n_ticks: int) -> dict:
    """Paged vs dense: throughput with the lean fused kernel, KV memory
    footprint, and the oversubscription headline — more in-flight slots
    than the same token budget could hold densely."""
    import numpy as np

    from repro.serving.engine import DecodeEngine, Request

    # throughput + memory: identical workload, paged vs dense engine.
    # page_size matches the dense engine's tile (64) so both walk the same
    # schedule signatures — the comparison isolates the page-table
    # indirection, not bucket-transition trace costs (which interpret mode
    # inflates ~1000x vs a real accelerator; see EXPERIMENTS.md).
    eng_dense = _mk_engine(cfg, params, "lean", use_fast_path=True, fused=True)
    tps_dense, _ = _ticks_per_sec(eng_dense, cfg, n_ticks)
    eng_paged = _mk_engine(
        cfg, params, "lean", use_fast_path=True, fused=True,
        paged=True, page_size=eng_dense.tile,
    )
    tps_paged, _ = _ticks_per_sec(eng_paged, cfg, n_ticks)

    # oversubscription demo: 8 slots backed by a pool holding only the
    # dense-4-slot token budget; lazy paging lets all 8 run concurrently
    ps, pps = 16, 64 // 16
    from repro.serving.config import EngineConfig, PagedConfig

    eng_over = DecodeEngine(cfg, params, config=EngineConfig(
        max_batch=8, cache_len=64, attn_backend="ref",
        paged=PagedConfig(enabled=True, page_size=ps, num_pages=1 + 4 * pps),
    ))
    rng = np.random.default_rng(0)
    for uid in range(8):
        eng_over.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab_size, 8),
            max_new_tokens=12,
        ))
    max_active = 0
    for _ in range(40):
        eng_over.tick()
        max_active = max(
            max_active, sum(1 for r in eng_over.slot_req if r is not None)
        )
        if not eng_over.queue and not any(eng_over.slot_req):
            break
    eng_over.pool.check()

    return {
        "ticks_per_sec_dense": tps_dense,
        "ticks_per_sec_paged": tps_paged,
        "paged_over_dense_throughput": tps_paged / tps_dense,
        "attn_kv_bytes_dense": _attn_kv_bytes(eng_dense),
        "attn_kv_bytes_paged": _attn_kv_bytes(eng_paged),
        "schedule_cache_paged": eng_paged.sched_cache.stats.as_dict(),
        "pool": eng_paged.stats.kv_pool,
        "oversubscription": {
            "slots": 8,
            "dense_equivalent_slots": 4,
            "max_concurrent_slots": max_active,
            "preemptions": eng_over.stats.preemptions,
            "pool_high_water": eng_over.stats.kv_pool.get("high_water", 0),
        },
    }


def _run_scheduler_section(cfg, params) -> dict:
    """Mixed prefill+decode workload: does a long prompt stall the decode
    batch? Compares the chunked-prefill scheduler against the blocking-admit
    scheduler on identical traffic (3 steady decoders + 1 long prompt).

    The headline invariant: with chunked prefill, decode tokens keep
    flowing in the ticks where the long request is still PREFILLING
    (``decode_tokens_while_long_prefilling > 0``); with blocking admission
    the whole prompt runs inside one admission step and that count is 0 by
    construction while the wall-clock of the worst step balloons.
    """
    import time

    import numpy as np

    from repro.serving.engine import DecodeEngine
    from repro.serving.scheduler import (
        RequestState, Scheduler, SchedulerConfig,
    )

    LONG, CHUNK = 48, 8
    out: dict = {"workload": {
        "steady_decoders": 3, "long_prompt_tokens": LONG,
        "chunk_size": CHUNK, "token_budget": 16,
    }}
    from repro.serving.config import EngineConfig, PagedConfig

    for mode in ("chunked", "blocking"):
        eng = DecodeEngine(cfg, params, config=EngineConfig(
            max_batch=4, cache_len=64, attn_backend="lean", num_workers=8,
            paged=PagedConfig(enabled=True, page_size=16),
        ))
        sch = Scheduler(eng, SchedulerConfig(
            chunk_size=CHUNK, prefill_pack=1, token_budget=16,
            chunked=(mode == "chunked"),
        ))
        rng = np.random.default_rng(0)
        for i in range(3):
            sch.submit(rng.integers(0, cfg.vocab_size, 4), 1_000_000, uid=i)
        # warm ALL schedule signatures the measured window will touch:
        # first run the steady decoders across their last bucket boundary
        # (ctx 48 @ cache 64), then a throwaway long request so every
        # chunk/masked-decode trace is compiled before timing starts
        for _ in range(42):
            sch.step()
        warm = sch.submit(rng.integers(0, cfg.vocab_size, LONG), 2, uid=50)
        while not warm.done:
            sch.step()

        long = sch.submit(rng.integers(0, cfg.vocab_size, LONG), 2, uid=99)
        overlap_tokens = 0          # decode tokens in long-PREFILLING ticks
        overlap_ticks = 0
        step_walls = []
        while not long.done:
            t0 = time.perf_counter()
            toks = sch.step()
            step_walls.append(time.perf_counter() - t0)
            if long.state is RequestState.PREFILLING:
                overlap_ticks += 1
                overlap_tokens += len(toks)
        out[mode] = {
            "decode_tokens_while_long_prefilling": overlap_tokens,
            "long_prefilling_ticks": overlap_ticks,
            "ttft_long_s": long.first_token_time - long.arrival_time,
            "max_step_wall_s": max(step_walls),
            "mean_step_wall_s": sum(step_walls) / len(step_walls),
            "telemetry": sch.telemetry(),
        }
    out["no_stall"] = (
        out["chunked"]["decode_tokens_while_long_prefilling"] > 0
    )
    return out


def _run_hardening_section(cfg, params, n_ticks: int) -> dict:
    """Hardening overhead: the same paged lean-fused engine, plain vs
    hardened (guards configured, fault injector attached but *disabled*).
    The acceptance contract is "zero overhead when disabled": the
    throughput ratio must stay within 3% (gated by
    ``benchmarks.check_regression``). Rounds alternate plain/hardened on
    the same host and the reported ratio is the median of per-round
    ratios, so shared-runner drift hits both sides equally. Within a
    round each tick is timed individually and the round's estimate is
    the MEDIAN per-tick time: under interpret mode a bucket-boundary
    retrace (~1.5s vs ~1.3ms steady ticks) lands at the same tick index
    for both engines but its *trace* time differs between the two
    programs, so whole-round sums would measure compile noise, not the
    per-tick guard cost.
    """
    import statistics

    from repro.serving.faults import FaultInjector
    from repro.serving.guards import GuardConfig

    def mk(hardened: bool):
        kw = {}
        if hardened:
            kw["faults"] = FaultInjector({}, enabled=False)
            kw["guards"] = GuardConfig(audit_interval=32)
        return _mk_engine(
            cfg, params, "lean", use_fast_path=True, fused=True,
            paged=True, page_size=16, **kw,
        )

    def median_tick_s(eng, n):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            eng.tick()
            ts.append(time.perf_counter() - t0)
        return statistics.median(ts)

    eng_plain, eng_hard = mk(False), mk(True)
    # one warmup pass each (feeds steady-state traffic + compiles traces)
    _ticks_per_sec(eng_plain, cfg, 2)
    _ticks_per_sec(eng_hard, cfg, 2)

    # steady ticks are ~1-2 ms, so generous sampling is cheap once the
    # retrace outliers are excluded by the per-tick median
    rounds, per_round = 5, max(9, n_ticks)
    ratios, tps_p_all, tps_h_all = [], [], []
    for _ in range(rounds):
        tick_p = median_tick_s(eng_plain, per_round)
        tick_h = median_tick_s(eng_hard, per_round)
        tps_p_all.append(1.0 / tick_p)
        tps_h_all.append(1.0 / tick_h)
        ratios.append(tick_p / tick_h)

    assert eng_hard.stats.nan_ticks == 0
    assert eng_hard.stats.audit_failures == 0
    return {
        "ticks_per_sec_plain": statistics.median(tps_p_all),
        "ticks_per_sec_hardened": statistics.median(tps_h_all),
        "hardened_over_plain_throughput": statistics.median(ratios),
        "rounds": rounds,
        "ticks_per_round": per_round,
        "audits_run": eng_hard.stats.audits_run,
        "injector_fires": eng_hard.faults.total_fires,
    }


def _run_observability_section(cfg, params, n_ticks: int,
                               flight_out: str = "FLIGHT_sample.json") -> dict:
    """Tracing overhead: the same paged lean-fused engine, untraced
    (``NULL_TRACER`` default — the production setting) vs traced (an
    enabled :class:`repro.obs.trace.Tracer`, which also times a
    ``block_until_ready`` per decode span for sync attribution). The
    acceptance contract mirrors the hardening one: the traced/untraced
    throughput ratio must stay >= 0.97 (gated by
    ``benchmarks.check_regression``). The protocol tightens the
    hardening section's alternating *rounds* to alternating *ticks*:
    within a round each engine ticks in lockstep (plain, traced, plain,
    traced, ...) and the round's estimate is the per-engine median tick
    time — on a shared host, drift over a whole round (~10%) dwarfs the
    microsecond-level span cost being measured, and pairwise
    interleaving puts both engines inside the same drift window.

    Also writes ``flight_out``: a real flight-recorder postmortem bundle
    from a one-shot injected ``nan_output`` fault (CI uploads it as an
    inspectable artifact next to BENCH_decode_step.json).
    """
    import statistics

    from repro.obs.trace import Tracer
    from repro.serving.faults import FaultInjector, FaultSpec
    from repro.serving.guards import GuardConfig

    def mk(traced: bool):
        kw = {"tracer": Tracer()} if traced else {}
        return _mk_engine(
            cfg, params, "lean", use_fast_path=True, fused=True,
            paged=True, page_size=16, **kw,
        )

    eng_plain, eng_traced = mk(False), mk(True)
    _ticks_per_sec(eng_plain, cfg, 4)
    _ticks_per_sec(eng_traced, cfg, 4)

    rounds, per_round = 5, max(9, n_ticks)
    ratios, tps_u_all, tps_t_all = [], [], []
    for _ in range(rounds):
        tu, tt = [], []
        for _ in range(per_round):
            t0 = time.perf_counter()
            eng_plain.tick()
            tu.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            eng_traced.tick()
            tt.append(time.perf_counter() - t0)
        tick_u = statistics.median(tu)
        tick_t = statistics.median(tt)
        tps_u_all.append(1.0 / tick_u)
        tps_t_all.append(1.0 / tick_t)
        ratios.append(tick_u / tick_t)

    spans = eng_traced.tracer.spans
    dk = [s for s in spans if s["name"] == "decode_kernel"]
    sync_ms = (
        statistics.median([s.get("sync_ms", 0.0) for s in dk])
        if dk else 0.0
    )

    # sample postmortem artifact: a short hardened run with one injected
    # transient-NaN fault, dumped through the real guard path
    inj = FaultInjector(
        {"nan_output": FaultSpec(rate=1.0, start=3, max_fires=1)}, seed=1
    )
    eng_f = _mk_engine(
        cfg, params, "lean", use_fast_path=True, fused=True,
        paged=True, page_size=16, faults=inj,
        guards=GuardConfig(heal_after=2),
    )
    _feed(eng_f, cfg, n=3)
    for _ in range(10):
        eng_f.tick()
    sample = eng_f.flight.dump("ci-sample", path=flight_out)

    return {
        "ticks_per_sec_untraced": statistics.median(tps_u_all),
        "ticks_per_sec_traced": statistics.median(tps_t_all),
        "traced_over_untraced_throughput": statistics.median(ratios),
        "rounds": rounds,
        "ticks_per_round": per_round,
        "spans_recorded": len(spans),
        "decode_sync_ms_median": sync_ms,
        "flight_sample": {
            "path": flight_out,
            "events": len(sample["events"]),
            "fault_fires": sum(
                1 for ev in sample["events"]
                if ev["kind"] == "fault_fire"
            ),
            "injector_fires": inj.total_fires,
        },
    }


def _run_quant_section(cfg, params, n_ticks: int) -> dict:
    """int8 page quantization: effective pool capacity per byte vs bf16
    (the headline — page_bytes straight from the pool's layout
    descriptor, scale sidecar included), device KV footprint, decode
    throughput, and a greedy-token agreement probe (quantization may
    legitimately flip a near-tie argmax, so agreement is a fraction, not
    an identity)."""
    import numpy as np

    from repro.serving.engine import DecodeEngine, Request

    def mk(**kw):
        return _mk_engine(
            cfg, params, "lean", use_fast_path=True, fused=True,
            paged=True, page_size=16, **kw,
        )

    eng_bf16 = mk()
    tps_bf16, _ = _ticks_per_sec(eng_bf16, cfg, n_ticks)
    eng_int8 = mk(kv_dtype="int8")
    tps_int8, _ = _ticks_per_sec(eng_int8, cfg, n_ticks)

    lay16, lay8 = eng_bf16.pool.layout, eng_int8.pool.layout
    capacity = lay16.page_bytes / lay8.page_bytes

    # token-agreement probe on fresh engines (finite requests, greedy)
    def streams(**kw):
        eng = mk(**kw)
        rng = np.random.default_rng(3)
        reqs = [
            Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 6 + 3 * i),
                    max_new_tokens=12)
            for i in range(3)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion(max_ticks=200)
        return [r.generated for r in reqs]

    base, quant = streams(), streams(kv_dtype="int8")
    agree = sum(
        x == y for a, b in zip(base, quant) for x, y in zip(a, b)
    )
    total = sum(len(a) for a in base)

    return {
        "layout_bf16": lay16.as_dict(),
        "layout_int8": lay8.as_dict(),
        "capacity_ratio_vs_bf16": capacity,
        "kv_bytes_per_token_bf16": lay16.page_bytes / lay16.page_size,
        "kv_bytes_per_token_int8": lay8.page_bytes / lay8.page_size,
        "attn_kv_bytes_bf16": _attn_kv_bytes(eng_bf16),
        "attn_kv_bytes_int8": _attn_kv_bytes(eng_int8),
        "ticks_per_sec_bf16": tps_bf16,
        "ticks_per_sec_int8": tps_int8,
        "int8_over_bf16_throughput": tps_int8 / tps_bf16,
        "token_agreement": agree / total,
        "tokens_compared": total,
    }


def _run_speculative_section(cfg, params) -> dict:
    """Draft-verify speculative decode: tokens/sec vs k with the synthetic
    100%-accept oracle proposer (replaying the non-spec greedy streams).
    Every draft verifies, so this measures the pure kernel-amortization
    ceiling — one stream-K sweep scoring k+1 rows instead of 1. Output is
    asserted token-identical to the non-spec baseline at every k (the
    safety contract is part of the bench, not just the test suite)."""
    import time as _time

    import numpy as np

    from repro.serving.config import EngineConfig, PagedConfig, SpecConfig
    from repro.serving.engine import DecodeEngine, Request
    from repro.serving.speculative import OracleProposer

    NEW = 24

    def reqs():
        rng = np.random.default_rng(7)
        return [
            Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8 + 5 * i),
                    max_new_tokens=NEW)
            for i in range(3)
        ]

    def mk(spec=None):
        return DecodeEngine(cfg, params, config=EngineConfig(
            max_batch=4, cache_len=64, attn_backend="lean", num_workers=8,
            paged=PagedConfig(enabled=True, page_size=8),
            spec=spec if spec is not None else SpecConfig(),
        ))

    def timed_run(eng):
        rs = reqs()
        for r in rs:
            eng.submit(r)
        t0 = _time.perf_counter()
        eng.run_to_completion(max_ticks=600)
        dt = _time.perf_counter() - t0
        toks = sum(len(r.generated) for r in rs)
        return {r.uid: list(r.generated) for r in rs}, toks / dt

    # non-spec greedy baseline: records the oracle streams + tokens/sec.
    # one throwaway run warms the jit caches so compile time (inflated
    # ~1000x by interpret mode) stays out of every measured number.
    timed_run(mk())
    streams, tps_base = timed_run(mk())

    out: dict = {"tokens_per_sec_nonspec": tps_base, "new_tokens": NEW,
                 "accept_rate": 1.0, "by_k": {}}
    for k in (1, 2, 4, 8):
        spec = SpecConfig(enabled=True, k=k,
                          proposer=OracleProposer(streams))
        timed_run(mk(spec))                      # warm this k's traces
        eng = mk(spec)
        got, tps = timed_run(eng)
        assert got == streams, f"speculative k={k} diverged from greedy"
        out["by_k"][str(k)] = {
            "tokens_per_sec": tps,
            "speedup_vs_nonspec": tps / tps_base,
            "spec_ticks": eng.stats.spec_ticks,
            "drafted": eng.stats.spec_draft_tokens,
            "accepted": eng.stats.spec_accepted_tokens,
        }
    out["spec_speedup_k4"] = out["by_k"]["4"]["speedup_vs_nonspec"]
    return out


def run_decode_step(n_ticks: int = 24, out_path: str = "BENCH_decode_step.json",
                    rows: list | None = None,
                    history_path: str | None = "BENCH_history.jsonl") -> dict:
    import jax

    jax.config.update("jax_platform_name", "cpu")

    from repro.configs import get_smoke_config
    from repro.models import init_params

    from benchmarks.trajectory import (
        append_history,
        env_fingerprint,
        new_run_id,
    )

    cfg = get_smoke_config("mistral-nemo-12b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    # environment fingerprint + run id: the absolute-trajectory gate in
    # check_regression compares like-fingerprint history only, and uses
    # run_id to exclude this very run's freshly appended record
    fingerprint = env_fingerprint()
    run_id = new_run_id()
    result: dict = {"config": {
        "arch": "mistral-nemo-12b(smoke)", "max_batch": 4, "cache_len": 64,
        "num_workers": 8, "ticks": n_ticks, "platform": "cpu-interpret",
        "fingerprint": fingerprint, "run_id": run_id,
    }}

    # fast path (lean fused) — also collect host/device split
    eng_fast = _mk_engine(cfg, params, "lean", use_fast_path=True, fused=True)
    tps_fast, s_per_tick = _ticks_per_sec(eng_fast, cfg, n_ticks)
    dev_ms = _device_ms_per_tick(eng_fast)
    cache_stats = eng_fast.sched_cache.stats.as_dict()

    # legacy baseline (pre-PR behavior: per-tick schedule, unjitted step)
    eng_legacy = _mk_engine(cfg, params, "lean", use_fast_path=False)
    n_legacy = max(4, n_ticks // 4)          # it is slow; sample fewer ticks
    tps_legacy, _ = _ticks_per_sec(eng_legacy, cfg, n_legacy, warmup=1)

    # ref backend fast path for context (jnp attention, always jitted)
    eng_ref = _mk_engine(cfg, params, "ref", use_fast_path=True)
    tps_ref, _ = _ticks_per_sec(eng_ref, cfg, n_ticks)

    result["decode_step"] = {
        "ticks_per_sec_fast": tps_fast,
        "ticks_per_sec_legacy": tps_legacy,
        "ticks_per_sec_ref_backend": tps_ref,
        "speedup_vs_legacy": tps_fast / tps_legacy,
        "ms_per_tick_fast": s_per_tick * 1e3,
        "device_ms_per_tick": dev_ms,
        "host_ms_per_tick": max(0.0, s_per_tick * 1e3 - dev_ms),
        "schedule_cache": cache_stats,
    }
    result["paged"] = _run_paged_section(cfg, params, n_ticks)
    result["scheduler"] = _run_scheduler_section(cfg, params)
    result["hardening"] = _run_hardening_section(cfg, params, n_ticks)
    result["observability"] = _run_observability_section(
        cfg, params, n_ticks
    )
    result["quant"] = _run_quant_section(cfg, params, n_ticks)
    result["speculative"] = _run_speculative_section(cfg, params)
    Path(out_path).write_text(json.dumps(result, indent=1))
    if history_path:
        append_history(
            {
                "ticks_per_sec_fast": tps_fast,
                "ticks_per_sec_legacy": tps_legacy,
                "ms_per_tick_fast": s_per_tick * 1e3,
                "spec_speedup_k4": result["speculative"]["spec_speedup_k4"],
            },
            fingerprint=fingerprint,
            run_id=run_id,
            wall_time=time.time(),
            path=history_path,
        )
    if rows is not None:
        d = result["decode_step"]
        p = result["paged"]
        s = result["scheduler"]
        rows.append(("decode_step_fast_us_per_tick",
                     d["ms_per_tick_fast"] * 1e3, d["speedup_vs_legacy"]))
        rows.append(("decode_step_cache_hit_rate", 0.0,
                     cache_stats["hit_rate"]))
        rows.append(("decode_step_paged_over_dense", 0.0,
                     p["paged_over_dense_throughput"]))
        rows.append(("decode_step_paged_max_concurrent", 0.0,
                     float(p["oversubscription"]["max_concurrent_slots"])))
        rows.append(("sched_decode_toks_during_long_prefill", 0.0,
                     float(s["chunked"][
                         "decode_tokens_while_long_prefilling"])))
        rows.append(("sched_ttft_long_chunked_s",
                     s["chunked"]["ttft_long_s"],
                     s["blocking"]["ttft_long_s"]))
        rows.append(("decode_step_hardened_over_plain", 0.0,
                     result["hardening"]["hardened_over_plain_throughput"]))
        rows.append(("decode_step_traced_over_untraced", 0.0,
                     result["observability"][
                         "traced_over_untraced_throughput"]))
        qn = result["quant"]
        rows.append(("decode_step_quant_capacity_ratio", 0.0,
                     qn["capacity_ratio_vs_bf16"]))
        rows.append(("decode_step_quant_token_agreement", 0.0,
                     qn["token_agreement"]))
        sp = result["speculative"]
        rows.append(("decode_step_spec_speedup_k4", 0.0,
                     sp["spec_speedup_k4"]))
    return result


def run(rows: list, history_path="BENCH_history.jsonl"):
    run_decode_step(rows=rows, history_path=history_path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--out", default="BENCH_decode_step.json")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="trajectory store to append to ('' disables)")
    args = ap.parse_args()
    result = run_decode_step(args.ticks, args.out,
                             history_path=args.history or None)
    d = result["decode_step"]
    print(json.dumps(result, indent=1))
    p = result["paged"]
    print(
        f"\nfast {d['ticks_per_sec_fast']:.2f} ticks/s vs legacy "
        f"{d['ticks_per_sec_legacy']:.2f} ticks/s "
        f"({d['speedup_vs_legacy']:.1f}x); cache hit rate "
        f"{d['schedule_cache']['hit_rate']:.2f}; "
        f"host {d['host_ms_per_tick']:.1f}ms + device "
        f"{d['device_ms_per_tick']:.1f}ms per tick"
    )
    o = p["oversubscription"]
    print(
        f"paged {p['ticks_per_sec_paged']:.2f} ticks/s "
        f"({p['paged_over_dense_throughput']:.2f}x dense); "
        f"oversub: {o['max_concurrent_slots']}/{o['slots']} slots live on a "
        f"{o['dense_equivalent_slots']}-slot dense budget "
        f"({o['preemptions']} preemptions)"
    )
    s = result["scheduler"]
    print(
        f"scheduler: {s['chunked']['decode_tokens_while_long_prefilling']} "
        f"decode tokens flowed during the long prefill (chunked) vs "
        f"{s['blocking']['decode_tokens_while_long_prefilling']} (blocking); "
        f"worst step {s['chunked']['max_step_wall_s']*1e3:.0f}ms vs "
        f"{s['blocking']['max_step_wall_s']*1e3:.0f}ms"
    )
    h = result["hardening"]
    print(
        f"hardening: {h['ticks_per_sec_hardened']:.2f} ticks/s hardened vs "
        f"{h['ticks_per_sec_plain']:.2f} plain "
        f"({h['hardened_over_plain_throughput']:.3f}x, gate >= 0.97)"
    )
    ob = result["observability"]
    print(
        f"observability: {ob['ticks_per_sec_traced']:.2f} ticks/s traced "
        f"vs {ob['ticks_per_sec_untraced']:.2f} untraced "
        f"({ob['traced_over_untraced_throughput']:.3f}x, gate >= 0.97); "
        f"{ob['spans_recorded']} spans, median decode sync "
        f"{ob['decode_sync_ms_median']:.2f}ms; flight sample -> "
        f"{ob['flight_sample']['path']}"
    )
    qn = result["quant"]
    print(
        f"quant: {qn['capacity_ratio_vs_bf16']:.2f}x effective pool "
        f"capacity ({qn['kv_bytes_per_token_int8']:.0f} vs "
        f"{qn['kv_bytes_per_token_bf16']:.0f} KV bytes/token); "
        f"{qn['ticks_per_sec_int8']:.2f} ticks/s int8 vs "
        f"{qn['ticks_per_sec_bf16']:.2f} bf16; token agreement "
        f"{qn['token_agreement']:.2f}"
    )
    sp = result["speculative"]
    per_k = ", ".join(
        f"k={k}: {v['speedup_vs_nonspec']:.2f}x"
        for k, v in sp["by_k"].items()
    )
    print(
        f"speculative (oracle, accept=1.0): {per_k} over "
        f"{sp['tokens_per_sec_nonspec']:.2f} tok/s non-spec "
        f"(gate: k=4 >= 1.3x; output token-identical at every k)"
    )


if __name__ == "__main__":
    main()
