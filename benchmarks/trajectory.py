"""Perf-trajectory store: environment-fingerprinted bench history.

The ratio gates in :mod:`benchmarks.check_regression` catch *relative*
regressions (hardened vs plain, traced vs untraced) but are blind to the
whole stack getting slower together — the ROADMAP's "absolute perf gate"
gap. The trajectory store closes it: every ``decode_step`` bench run
appends one JSONL record to ``BENCH_history.jsonl`` carrying the
absolute throughput numbers plus an **environment fingerprint** (device
kind, jax platform, jax version; git sha recorded for forensics but not
matched), and the gate compares a fresh run only against
*like-fingerprint* history — CPU-interpret and TPU numbers never
cross-contaminate, and a laptop run never fails against CI's trajectory.

Each record also carries the run's own ``run_id`` so a gate executed in
the same invocation that appended the record can exclude it (a run
trivially matches itself).
"""
from __future__ import annotations

import json
import subprocess
import uuid
from pathlib import Path
from typing import List, Optional

__all__ = [
    "HISTORY_FORMAT_VERSION",
    "HISTORY_PATH",
    "env_fingerprint",
    "fingerprint_key",
    "new_run_id",
    "append_history",
    "load_history",
]

HISTORY_FORMAT_VERSION = 1
HISTORY_PATH = "BENCH_history.jsonl"


def env_fingerprint() -> dict:
    """Identity of the measuring environment. ``device``/``platform``/
    ``jax`` form the comparison key (:func:`fingerprint_key`);
    ``git_sha`` is informational. Never raises — a stripped container
    without git or an uninitialized backend degrades to "unknown"."""
    device = platform = "unknown"
    jax_version = "unknown"
    try:
        import jax

        jax_version = jax.__version__
        platform = jax.default_backend()
        device = jax.devices()[0].device_kind
    except Exception:
        pass
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=Path(__file__).resolve().parent,
        ).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    return {
        "device": device,
        "platform": platform,
        "jax": jax_version,
        "git_sha": sha,
    }


def fingerprint_key(fp: dict) -> tuple:
    """The like-for-like comparison key (sha intentionally excluded:
    code changes are exactly what the gate must see across)."""
    return (
        fp.get("device", "unknown"),
        fp.get("platform", "unknown"),
        fp.get("jax", "unknown"),
    )


def new_run_id() -> str:
    return uuid.uuid4().hex[:12]


def append_history(
    metrics: dict,
    *,
    fingerprint: Optional[dict] = None,
    run_id: Optional[str] = None,
    wall_time: Optional[float] = None,
    path=HISTORY_PATH,
) -> dict:
    """Append one trajectory record; returns it. ``metrics`` holds the
    absolute numbers the gate compares (``ticks_per_sec_fast`` first
    among them)."""
    record = {
        "format": HISTORY_FORMAT_VERSION,
        "run_id": run_id or new_run_id(),
        "fingerprint": fingerprint or env_fingerprint(),
        "metrics": dict(metrics),
    }
    if wall_time is not None:
        record["wall_time"] = wall_time
    p = Path(path)
    with p.open("a") as fh:
        fh.write(json.dumps(record, sort_keys=True) + "\n")
    return record


def load_history(path=HISTORY_PATH) -> List[dict]:
    """All parseable records, file order. Corrupt lines are skipped —
    a truncated append must not brick the gate."""
    p = Path(path)
    if not p.exists():
        return []
    out = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict) and isinstance(rec.get("metrics"), dict):
            out.append(rec)
    return out
