"""Paper Fig. 2 (phase timeshare) + Fig. 12 (end-to-end speedup, Phi-3-
Medium-like, prompt:output = 8:1).

End-to-end time = prefill + sum over decode steps of (attention + other
layers). Attention per decode step comes from the schedule model (LA vs
FD); non-attention decode time and prefill are schedule-independent, so the
e2e speedup is diluted attention speedup — which is why the paper's Fig. 12
numbers (1.1-1.7x) sit far below the kernel-level 2x. We reproduce that
dilution curve.

Also runs a real reduced-config e2e generation through the DecodeEngine on
CPU with both backends to confirm token-identical outputs.
"""
from __future__ import annotations

import numpy as np

from repro.core.leantile import default_tile_size
from .occupancy_model import A100, fd_makespan, lean_makespan


# Phi-3 Medium-ish: 40 q heads, 10 kv heads, d=128, 40 layers, d_model 5120
HEADS_KV, HD, LAYERS = 10, 128, 40
HBM_BW = 2.0e12   # A100 80GB


def _phase_times(prompt: int, out_tokens: int, sched: str) -> dict:
    """Per-phase seconds: prefill is dense-flop bound; decode attention is
    HBM bound and scheduled per the wave model (a LeanTile streams K+V =
    tile*hd*2*2 bytes; with all workers streaming concurrently each tile
    takes bytes*workers/BW); decode linear layers run narrow GEMMs at ~35%
    of peak."""
    tile = default_tile_size(HD)
    dev = A100
    n_params = 14e9
    prefill = 2 * n_params * prompt / 312e12
    other_per_tok = 2 * n_params / (312e12 * 0.35)
    tile_time = tile * HD * 2 * 2 * dev.workers / HBM_BW
    attn = 0.0
    steps = np.linspace(prompt, prompt + out_tokens, 16)
    for ctx in steps:
        ms = (
            lean_makespan([int(ctx)], HEADS_KV, tile, dev)
            if sched == "la"
            else fd_makespan([int(ctx)], HEADS_KV, tile, dev)
        )
        attn += ms * tile_time * LAYERS * (out_tokens / len(steps))
    other = other_per_tok * out_tokens
    return {"prefill": prefill, "attn": attn, "other": other}


def run(rows: list):
    for prompt in (1024, 8192, 65536, 131072):
        out_tokens = prompt // 8
        la = _phase_times(prompt, out_tokens, "la")
        fd = _phase_times(prompt, out_tokens, "fd")
        t_la = sum(la.values())
        t_fd = sum(fd.values())
        rows.append((f"fig12_prompt{prompt//1024}k_e2e_la_vs_fd",
                     t_la * 1e6, t_fd / t_la))
        share = (fd["attn"] + fd["other"]) / t_fd
        rows.append((f"fig2_prompt{prompt//1024}k_decode_timeshare",
                     0.0, share))


def run_real_engine(rows: list):
    """Reduced-config end-to-end generation: lean vs fixed-split vs ref
    backends must emit IDENTICAL tokens (exact attention each)."""
    import time

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving.engine import DecodeEngine, Request

    cfg = get_smoke_config("mistral-nemo-12b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    outs = {}
    for backend in ("ref", "lean", "fixed"):
        from repro.serving.config import EngineConfig

        eng = DecodeEngine(cfg, params, config=EngineConfig(
            max_batch=2, cache_len=96, attn_backend=backend, num_workers=8,
        ))
        for uid in range(3):
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab_size, 12 + 5 * uid),
                               max_new_tokens=8))
        t0 = time.perf_counter()
        stats = eng.run_to_completion(max_ticks=64)
        dt = (time.perf_counter() - t0) * 1e6 / max(stats.ticks, 1)
        outs[backend] = [
            r if isinstance(r, list) else r for r in [stats.tokens_generated]
        ]
        rows.append((f"engine_{backend}_us_per_tick", dt,
                     stats.tokens_generated))
    assert outs["ref"] == outs["lean"] == outs["fixed"], outs
