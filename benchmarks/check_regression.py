"""CI perf-regression gate over ``BENCH_decode_step.json``.

Compares the freshly-produced benchmark artifact against the committed
``BENCH_baseline.json`` and fails (exit 1) when any gated metric regresses
by more than ``--threshold`` (default 15%).

Gated metrics are *intra-run ratios and counts* — speedup-vs-legacy,
paged-vs-dense throughput ratio, cascade-vs-baseline decode speedup,
tokens-decoded-while-prefilling — rather than absolute wall-clock
numbers, because shared CI runners make absolute timings jitter far more
than 15% while the within-run ratios stay stable (both sides of a ratio
see the same noisy host). A metric *missing* from the current artifact is
itself a failure, and the gate distinguishes the two ways that happens:
``FAIL (missing suite)`` when the suite's whole top-level section is
absent (the bench section didn't run — e.g. a crashed or silently-skipped
suite), vs ``FAIL (metric missing)`` when the section ran but no longer
reports the gated metric (a rename/refactor broke the contract). A
metric missing from the baseline is skipped with a note (new suites gate
once the baseline is refreshed).

Per-metric thresholds: ``THRESHOLDS`` overrides the CLI threshold for
metrics with a tighter contract — the hardening-overhead ratio (hardened
engine vs plain, both fault-free) is gated at 3%, the "zero overhead when
disabled" acceptance bar, not the 15% noise bar. ``FLOORS`` adds absolute
hard floors checked before the relative gate: the speculative speedup at
k=4 must stay >= 1.0x regardless of what the baseline recorded — below
parity the feature costs more than it amortizes.

**Absolute-trajectory gate**: the ratio gates above are blind to the
whole stack slowing down together, so the gate also compares the current
run's *absolute* ``ticks_per_sec_fast`` against the trajectory store
(``BENCH_history.jsonl``, see :mod:`benchmarks.trajectory`) — but only
against records whose environment fingerprint (device kind, jax
platform, jax version) matches the current artifact's, so CPU-interpret
and TPU numbers never cross-contaminate. The current run's own record
(matched by ``run_id``) is excluded, the comparison point is the median
of the last ``--trajectory-window`` like-fingerprint records, and a drop
beyond ``--threshold`` fails. No matching history → the trajectory gate
skips (first run on new hardware establishes the trajectory instead of
failing it).

``--inject-regression F`` scales every current metric by ``F`` before
comparison — the self-test knob that demonstrates the gate trips (e.g.
``--inject-regression 0.8`` must exit 1 against any baseline of itself).

``--update-baseline`` regenerates ``BENCH_baseline.json`` from the
current artifact with the clamp-to-1.0 rules applied automatically: the
parity-ratio metrics (hardening, observability) are capped at 1.0 so a
lucky faster-than-plain draw can never ratchet the bar above parity.

  PYTHONPATH=src python -m benchmarks.check_regression
  PYTHONPATH=src python -m benchmarks.check_regression --inject-regression 0.8
  PYTHONPATH=src python -m benchmarks.check_regression --update-baseline
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

from benchmarks.trajectory import fingerprint_key, load_history

# suite -> (json path, higher-is-better metric)
METRICS = {
    "decode_fast_path": ("decode_step", "speedup_vs_legacy"),
    "paged": ("paged", "paged_over_dense_throughput"),
    "scheduler": (
        "scheduler", "chunked", "decode_tokens_while_long_prefilling",
    ),
    "prefix_aliased": ("prefix", "headline", "decode_speedup_prefix"),
    "prefix_cascade": ("prefix", "headline", "decode_speedup_cascade"),
    "prefix_mixed_lcp_passes": (
        "prefix", "mixed_depth", "headline", "grouped_passes_per_tick_lcp",
    ),
    "prefix_mixed_fused": (
        "prefix", "mixed_depth", "headline", "fused_over_two_call_speedup",
    ),
    "hardening": ("hardening", "hardened_over_plain_throughput"),
    "observability": ("observability", "traced_over_untraced_throughput"),
    "quant_capacity": ("quant", "capacity_ratio_vs_bf16"),
    "quant_agreement": ("quant", "token_agreement"),
    "speculative": ("speculative", "spec_speedup_k4"),
}

# absolute hard floors, checked before the relative gate: some metrics
# carry a meaningful zero point that no amount of baseline drift may
# cross — speculative decode below 1.0x means verify sweeps cost more
# than the tokens they amortize, i.e. the feature actively hurts
FLOORS = {
    "speculative": 1.0,
}

# per-metric regression thresholds overriding the CLI default: the
# fault-flags-disabled overhead of the hardened engine is an acceptance
# contract (< 3%), not a noise bar
THRESHOLDS = {
    "hardening": 0.03,
    # same contract for tracing: an *enabled* tracer must cost < 3%
    # (disabled tracing is structurally free — a shared no-op span)
    "observability": 0.03,
    # layout math, not wall-clock: any drop means the dtype accounting
    # (page_bytes / scale sidecar) regressed, so gate it tight
    "quant_capacity": 0.01,
    # greedy decode on fixed seeds is deterministic on the CI host; a
    # real numerics regression moves agreement far more than 5%
    "quant_agreement": 0.05,
}


def _lookup(doc: dict, path):
    cur = doc
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return float(cur) if isinstance(cur, (int, float)) else None


def check(current: dict, baseline: dict, threshold: float = 0.15,
          scale: float = 1.0):
    """Returns (rows, failures): one row per gated metric with the
    comparison verdict. ``scale`` multiplies the current value (the
    regression-injection knob)."""
    rows, failures = [], []
    for suite, path in METRICS.items():
        base = _lookup(baseline, path)
        cur = _lookup(current, path)
        if cur is not None:
            cur *= scale
        floor = FLOORS.get(suite)
        if floor is not None and cur is not None and cur < floor:
            rows.append((suite, base, cur, None,
                         f"FAIL (below floor {floor:g})"))
            failures.append(suite)
            continue
        if base is None:
            verdict = ("ok (floor only, no baseline)"
                       if floor is not None and cur is not None
                       else "skip (no baseline)")
            rows.append((suite, base, cur, None, verdict))
            continue
        if cur is None:
            # distinguish "the whole bench section never ran" from "the
            # section ran but the gated metric is gone"
            if path[0] not in current:
                verdict = "FAIL (missing suite)"
            else:
                verdict = "FAIL (metric missing)"
            rows.append((suite, base, cur, None, verdict))
            failures.append(suite)
            continue
        thr = THRESHOLDS.get(suite, threshold)
        ratio = cur / base if base else float("inf")
        if base > 0 and ratio < 1.0 - thr:
            rows.append((suite, base, cur, ratio, "FAIL (regression)"))
            failures.append(suite)
        else:
            rows.append((suite, base, cur, ratio, "ok"))
    return rows, failures


# absolute metrics gated against like-fingerprint trajectory history:
# metric name in the history record -> json path in the current artifact
TRAJECTORY_METRICS = {
    "ticks_per_sec_fast": ("decode_step", "ticks_per_sec_fast"),
}


def check_trajectory(current: dict, history: list, threshold: float = 0.15,
                     window: int = 5, scale: float = 1.0):
    """Absolute-trajectory gate: (rows, failures) like :func:`check`.

    Compares the current artifact's absolute numbers against the median
    of the last ``window`` history records with a *matching environment
    fingerprint*, excluding the current run's own record (it appends
    itself before the gate runs). No fingerprint / no comparable history
    → skip verdicts, never failures."""
    cfg = current.get("config") or {}
    fp = cfg.get("fingerprint")
    run_id = cfg.get("run_id")
    rows, failures = [], []
    if fp is None:
        for name in TRAJECTORY_METRICS:
            rows.append((name, None, None, None,
                         "skip (no fingerprint in artifact)"))
        return rows, failures
    key = fingerprint_key(fp)
    comparable = [
        r for r in history
        if fingerprint_key(r.get("fingerprint") or {}) == key
        and r.get("run_id") != run_id
    ]
    for name, path in TRAJECTORY_METRICS.items():
        cur = _lookup(current, path)
        if cur is not None:
            cur *= scale
        vals = [
            float(r["metrics"][name]) for r in comparable[-window:]
            if isinstance(r["metrics"].get(name), (int, float))
        ]
        if not vals:
            rows.append((name, None, cur,
                         None, "skip (no like-fingerprint history)"))
            continue
        base = statistics.median(vals)
        if cur is None:
            rows.append((name, base, None, None, "FAIL (metric missing)"))
            failures.append(name)
            continue
        ratio = cur / base if base else float("inf")
        if base > 0 and ratio < 1.0 - threshold:
            rows.append((name, base, cur, ratio, "FAIL (regression)"))
            failures.append(name)
        else:
            rows.append((name, base, cur, ratio, "ok"))
    return rows, failures


# suites whose gated metric is a parity ratio (hardened/plain,
# traced/untraced): clamp to 1.0 when refreshing the baseline so a lucky
# faster-than-parity draw never ratchets the bar above "no overhead"
CLAMP_SUITES = ("hardening", "observability")


def update_baseline(current: dict, out_path) -> list:
    """Regenerate the committed baseline from a bench artifact, applying
    the clamp-to-1.0 rules automatically. Returns the clamped suites.

    Top-level sections present in the existing baseline but absent from
    the current artifact are preserved — different bench entry points
    own different sections (``prefix_bench`` vs ``decode_step_bench``),
    and refreshing from one must not silently un-gate the other's
    metrics."""
    doc = json.loads(json.dumps(current))      # deep copy, JSON-clean
    out = Path(out_path)
    if out.exists():
        existing = json.loads(out.read_text())
        for section, val in existing.items():
            doc.setdefault(section, val)
    clamped = []
    for suite in CLAMP_SUITES:
        path = METRICS[suite]
        cur = doc
        for key in path[:-1]:
            if not isinstance(cur, dict) or key not in cur:
                cur = None
                break
            cur = cur[key]
        leaf = path[-1]
        if isinstance(cur, dict) and isinstance(cur.get(leaf), (int, float)):
            if cur[leaf] > 1.0:
                cur[leaf] = 1.0
                clamped.append(suite)
    Path(out_path).write_text(json.dumps(doc, indent=1))
    return clamped


def _print_rows(rows, names, header):
    w = max(len(s) for s in names)
    print(f"{header:<{w}}  {'baseline':>10}  {'current':>10}  "
          f"{'ratio':>7}  verdict")
    for suite, base, cur, ratio, verdict in rows:
        fb = f"{base:.4g}" if base is not None else "-"
        fc = f"{cur:.4g}" if cur is not None else "-"
        fr = f"{ratio:.3f}" if ratio is not None else "-"
        print(f"{suite:<{w}}  {fb:>10}  {fc:>10}  {fr:>7}  {verdict}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_decode_step.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.15)
    ap.add_argument(
        "--inject-regression", type=float, default=1.0,
        help="scale current metrics by this factor (gate self-test)",
    )
    ap.add_argument(
        "--history", default="BENCH_history.jsonl",
        help="trajectory store for the absolute gate ('' disables)",
    )
    ap.add_argument(
        "--trajectory-window", type=int, default=5,
        help="like-fingerprint records the trajectory median is over",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="regenerate --baseline from --current (clamp rules applied) "
             "instead of gating",
    )
    args = ap.parse_args()

    cur_path, base_path = Path(args.current), Path(args.baseline)
    if not cur_path.exists():
        print(f"FAIL: current artifact {cur_path} not found — did the "
              "benchmarks run?")
        return 1
    current = json.loads(cur_path.read_text())

    if args.update_baseline:
        clamped = update_baseline(current, base_path)
        note = (
            f" (clamped to 1.0: {', '.join(clamped)})" if clamped else ""
        )
        print(f"regenerated {base_path} from {cur_path}{note}")
        return 0

    if not base_path.exists():
        print(f"FAIL: committed baseline {base_path} not found")
        return 1
    baseline = json.loads(base_path.read_text())
    rows, failures = check(
        current, baseline, args.threshold, args.inject_regression
    )
    _print_rows(rows, METRICS, "suite")

    if args.history:
        history = load_history(args.history)
        t_rows, t_failures = check_trajectory(
            current, history, args.threshold,
            args.trajectory_window, args.inject_regression,
        )
        print()
        _print_rows(t_rows, TRAJECTORY_METRICS, "trajectory")
        failures += [f"trajectory:{n}" for n in t_failures]

    if failures:
        print(f"\nperf gate FAILED (> {args.threshold:.0%} regression): "
              + ", ".join(failures))
        return 1
    print(f"\nperf gate passed (threshold {args.threshold:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
