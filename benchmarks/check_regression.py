"""CI perf-regression gate over ``BENCH_decode_step.json``.

Compares the freshly-produced benchmark artifact against the committed
``BENCH_baseline.json`` and fails (exit 1) when any gated metric regresses
by more than ``--threshold`` (default 15%).

Gated metrics are *intra-run ratios and counts* — speedup-vs-legacy,
paged-vs-dense throughput ratio, cascade-vs-baseline decode speedup,
tokens-decoded-while-prefilling — rather than absolute wall-clock
numbers, because shared CI runners make absolute timings jitter far more
than 15% while the within-run ratios stay stable (both sides of a ratio
see the same noisy host). A metric *missing* from the current artifact is
itself a failure, and the gate distinguishes the two ways that happens:
``FAIL (missing suite)`` when the suite's whole top-level section is
absent (the bench section didn't run — e.g. a crashed or silently-skipped
suite), vs ``FAIL (metric missing)`` when the section ran but no longer
reports the gated metric (a rename/refactor broke the contract). A
metric missing from the baseline is skipped with a note (new suites gate
once the baseline is refreshed).

Per-metric thresholds: ``THRESHOLDS`` overrides the CLI threshold for
metrics with a tighter contract — the hardening-overhead ratio (hardened
engine vs plain, both fault-free) is gated at 3%, the "zero overhead when
disabled" acceptance bar, not the 15% noise bar.

``--inject-regression F`` scales every current metric by ``F`` before
comparison — the self-test knob that demonstrates the gate trips (e.g.
``--inject-regression 0.8`` must exit 1 against any baseline of itself).

  PYTHONPATH=src python -m benchmarks.check_regression
  PYTHONPATH=src python -m benchmarks.check_regression --inject-regression 0.8
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# suite -> (json path, higher-is-better metric)
METRICS = {
    "decode_fast_path": ("decode_step", "speedup_vs_legacy"),
    "paged": ("paged", "paged_over_dense_throughput"),
    "scheduler": (
        "scheduler", "chunked", "decode_tokens_while_long_prefilling",
    ),
    "prefix_aliased": ("prefix", "headline", "decode_speedup_prefix"),
    "prefix_cascade": ("prefix", "headline", "decode_speedup_cascade"),
    "prefix_mixed_lcp_passes": (
        "prefix", "mixed_depth", "headline", "grouped_passes_per_tick_lcp",
    ),
    "prefix_mixed_fused": (
        "prefix", "mixed_depth", "headline", "fused_over_two_call_speedup",
    ),
    "hardening": ("hardening", "hardened_over_plain_throughput"),
    "observability": ("observability", "traced_over_untraced_throughput"),
    "quant_capacity": ("quant", "capacity_ratio_vs_bf16"),
    "quant_agreement": ("quant", "token_agreement"),
}

# per-metric regression thresholds overriding the CLI default: the
# fault-flags-disabled overhead of the hardened engine is an acceptance
# contract (< 3%), not a noise bar
THRESHOLDS = {
    "hardening": 0.03,
    # same contract for tracing: an *enabled* tracer must cost < 3%
    # (disabled tracing is structurally free — a shared no-op span)
    "observability": 0.03,
    # layout math, not wall-clock: any drop means the dtype accounting
    # (page_bytes / scale sidecar) regressed, so gate it tight
    "quant_capacity": 0.01,
    # greedy decode on fixed seeds is deterministic on the CI host; a
    # real numerics regression moves agreement far more than 5%
    "quant_agreement": 0.05,
}


def _lookup(doc: dict, path):
    cur = doc
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return float(cur) if isinstance(cur, (int, float)) else None


def check(current: dict, baseline: dict, threshold: float = 0.15,
          scale: float = 1.0):
    """Returns (rows, failures): one row per gated metric with the
    comparison verdict. ``scale`` multiplies the current value (the
    regression-injection knob)."""
    rows, failures = [], []
    for suite, path in METRICS.items():
        base = _lookup(baseline, path)
        cur = _lookup(current, path)
        if cur is not None:
            cur *= scale
        if base is None:
            rows.append((suite, base, cur, None, "skip (no baseline)"))
            continue
        if cur is None:
            # distinguish "the whole bench section never ran" from "the
            # section ran but the gated metric is gone"
            if path[0] not in current:
                verdict = "FAIL (missing suite)"
            else:
                verdict = "FAIL (metric missing)"
            rows.append((suite, base, cur, None, verdict))
            failures.append(suite)
            continue
        thr = THRESHOLDS.get(suite, threshold)
        ratio = cur / base if base else float("inf")
        if base > 0 and ratio < 1.0 - thr:
            rows.append((suite, base, cur, ratio, "FAIL (regression)"))
            failures.append(suite)
        else:
            rows.append((suite, base, cur, ratio, "ok"))
    return rows, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_decode_step.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.15)
    ap.add_argument(
        "--inject-regression", type=float, default=1.0,
        help="scale current metrics by this factor (gate self-test)",
    )
    args = ap.parse_args()

    cur_path, base_path = Path(args.current), Path(args.baseline)
    if not cur_path.exists():
        print(f"FAIL: current artifact {cur_path} not found — did the "
              "benchmarks run?")
        return 1
    if not base_path.exists():
        print(f"FAIL: committed baseline {base_path} not found")
        return 1
    current = json.loads(cur_path.read_text())
    baseline = json.loads(base_path.read_text())
    rows, failures = check(
        current, baseline, args.threshold, args.inject_regression
    )

    w = max(len(s) for s in METRICS)
    print(f"{'suite':<{w}}  {'baseline':>10}  {'current':>10}  "
          f"{'ratio':>7}  verdict")
    for suite, base, cur, ratio, verdict in rows:
        fb = f"{base:.4g}" if base is not None else "-"
        fc = f"{cur:.4g}" if cur is not None else "-"
        fr = f"{ratio:.3f}" if ratio is not None else "-"
        print(f"{suite:<{w}}  {fb:>10}  {fc:>10}  {fr:>7}  {verdict}")
    if failures:
        print(f"\nperf gate FAILED (> {args.threshold:.0%} regression): "
              + ", ".join(failures))
        return 1
    print(f"\nperf gate passed (threshold {args.threshold:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
