"""Analytic wave/occupancy/latency model of the three attention schedules.

This reproduces the paper's evaluation methodology on hardware we don't
have: a device is W equal workers (GPU: SMs x CTAs-per-SM; TPU: cores x
pipeline slots). A decode-attention problem is (batch, kv-heads, ctx, tile):

  FlashAttention-2: one CTA per (batch, head) segment; no ctx parallelism.
      makespan = tiles_per_seg * ceil(segments / W)
  FlashDecoding:   fixed split s (paper's heuristic: smallest s covering W);
      makespan = ceil(tiles/s) * ceil(segments*s / W) + s * eps_reduce
  LeanAttention:   stream-K — total tiles split exactly evenly;
      makespan = ceil(total_tiles / W) + eps_reduce  (single fused launch,
      constant reduction overhead — paper §IV-C)

All times in LeanTile units; eps_launch per kernel launch (FD pays 2:
attention + reduction kernels), eps_reduce per merge of one partial.
This is the model behind every paper-figure benchmark; EXPERIMENTS.md
compares its outputs against the paper's measured speedups.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


from repro.core.leantile import fixed_split_factor


@dataclass(frozen=True)
class Device:
    name: str
    workers: int            # SMs x max CTAs/SM (GPU) | cores x pipe (TPU)
    eps_reduce: float = 0.15   # cost of merging one partial, in tile units
    eps_launch: float = 2.0    # kernel launch overhead, in tile units


A100 = Device("A100", workers=108 * 2)
H100 = Device("H100", workers=132 * 2)
A100x8 = Device("8xA100", workers=864 * 2)
TPU_V5E = Device("TPUv5e-core", workers=16)   # 2 TensorCores x 8 pipe slots


def tiles_of(ctx: int, tile: int) -> int:
    return -(-ctx // tile)


def fa2_makespan(lens: Sequence[int], H: int, tile: int, dev: Device):
    segs = len(lens) * H
    waves = -(-segs // dev.workers)
    # heterogeneous: each wave bounded by its slowest member; with one wave
    # per segment-batch the max length dominates
    t = tiles_of(max(lens), tile)
    return t * waves + dev.eps_launch


def fd_makespan(lens: Sequence[int], H: int, tile: int, dev: Device):
    segs = len(lens) * H
    s = fixed_split_factor(max(lens), segs, tile, dev.workers)
    t_split = -(-tiles_of(max(lens), tile) // s)
    waves = -(-(segs * s) // dev.workers)
    red = dev.eps_reduce * s + (dev.eps_launch if s > 1 else 0.0)
    return t_split * waves + red + dev.eps_launch


def lean_makespan(lens: Sequence[int], H: int, tile: int, dev: Device):
    total = sum(tiles_of(c, tile) for c in lens) * H
    return -(-total // dev.workers) + dev.eps_reduce + dev.eps_launch


def occupancy(lens: Sequence[int], H: int, tile: int, dev: Device,
              makespan: float) -> float:
    total = sum(tiles_of(c, tile) for c in lens) * H
    return min(1.0, total / (dev.workers * max(makespan, 1e-9)))


def speedups(lens: Sequence[int], H: int, tile: int, dev: Device) -> dict:
    fa2 = fa2_makespan(lens, H, tile, dev)
    fd = fd_makespan(lens, H, tile, dev)
    la = lean_makespan(lens, H, tile, dev)
    return {
        "fa2": fa2,
        "fd": fd,
        "la": la,
        "la_vs_fd": fd / la,
        "la_vs_fa2": fa2 / la,
        "occ_fa2": occupancy(lens, H, tile, dev, fa2),
        "occ_fd": occupancy(lens, H, tile, dev, fd),
        "occ_la": occupancy(lens, H, tile, dev, la),
    }
