"""Ragged-batch scheduling demo (paper Fig. 6/10): watch the stream-K
schedule keep every worker's tile count equal as context lengths diverge.

  PYTHONPATH=src python examples/ragged_serving.py
"""
import numpy as np

from repro.core.leantile import make_schedule
from benchmarks.occupancy_model import A100, speedups

print("ragged batch, 32 kv-heads, tile=256, A100-width device\n")
for ratio in (1.0, 0.75, 0.5, 0.25):
    max_ctx = 131072
    lens = [max_ctx] + [int(max_ctx * ratio * 0.9)] * 7
    s = speedups(lens, 32, 256, A100)
    sched = make_schedule(lens, 32, 256, A100.workers)
    print(f"avg/max={ratio:4.2f}: LA occupancy={s['occ_la']:.3f} "
          f"FD occupancy={s['occ_fd']:.3f} LA-vs-FD speedup={s['la_vs_fd']:.2f}x "
          f"(tiles/worker={sched.tiles_per_worker})")
