"""Ragged continuous batching, live (paper Fig. 6/10 in motion): wildly
different prompt lengths arrive together; the scheduler streams each prompt
into the paged pool chunk by chunk while every admitted sequence keeps
decoding — watch the per-tick prefill/decode token composition and the
stream-K schedule keep workers balanced as context lengths diverge.

  PYTHONPATH=src python examples/ragged_serving.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.config import EngineConfig, PagedConfig
from repro.serving.engine import DecodeEngine
from repro.serving.scheduler import RequestState, Scheduler, SchedulerConfig

cfg = get_smoke_config("mistral-nemo-12b")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

eng = DecodeEngine(cfg, params, config=EngineConfig(
    max_batch=4, cache_len=128, attn_backend="lean", num_workers=8,
    paged=PagedConfig(enabled=True, page_size=16),
))
sch = Scheduler(eng, SchedulerConfig(
    chunk_size=16, prefill_pack=2, token_budget=32, policy="priority",
    starvation_bound=16,
))

# a ragged burst: one long prompt among short ones (the decode batch must
# not stall behind the 96-token prefill), plus a late high-priority arrival
lens = [96, 9, 17, 33, 12]
handles = [
    sch.submit(rng.integers(0, cfg.vocab_size, L), max_new_tokens=10, uid=i)
    for i, L in enumerate(lens)
]
late = None

print(f"{'tick':>4} {'queue':>5} {'prefilling':>10} {'decoding':>8} "
      f"{'chunk toks':>10} {'decode toks':>11}")
for step in range(200):
    if step == 6:
        late = sch.submit(rng.integers(0, cfg.vocab_size, 7),
                          max_new_tokens=5, priority=5, uid=99)
        handles.append(late)
    pre = sum(1 for sr in sch.requests.values()
              if sr.state is RequestState.PREFILLING)
    dec = sum(1 for sr in sch.requests.values()
              if sr.state is RequestState.DECODING)
    chunk_before = sch.engine.stats.prefill_tokens
    out = sch.step()
    chunk_toks = sch.engine.stats.prefill_tokens - chunk_before
    if step < 14:
        print(f"{step:>4} {len(sch.queue):>5} {pre:>10} {dec:>8} "
              f"{chunk_toks:>10} {len(out):>11}")
    if not sch.pending:
        break

assert all(h.done for h in handles)
print(f"\ndrained in {sch.stats.steps} ticks: "
      f"{sch.stats.chunks} chunks, {sch.engine.stats.tokens_generated} "
      f"decode tokens, {sch.engine.stats.preemptions} preemptions")
if eng.stats.schedules:
    s = eng.stats.schedules[-1]
    print(f"last stream-K schedule: lens={s['lens']} "
          f"tiles={s['total_tiles']} over 8 workers x "
          f"{s['tiles_per_worker']} tiles/worker (pieces={s['pieces']})")
tel = sch.telemetry()
print(f"TTFT p50={tel['ttft']['p50']*1e3:.1f}ms  "
      f"p99={tel['ttft']['p99']*1e3:.1f}ms  "
      f"(high-priority late arrival waited "
      f"{(late.admit_step - late.arrival_step)} ticks in queue)")
eng.pool.check()
