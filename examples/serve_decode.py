"""Serve continuously-batched requests through the scheduler: chunked
stream-K prefill into the paged KV pool + fused lean decode ticks, with
per-token streaming callbacks and TTFT/TPOT telemetry. Compares all three
attention backends (token streams must be identical — exact attention
everywhere, only the schedule differs).

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.config import EngineConfig, PagedConfig, SpecConfig
from repro.serving.engine import DecodeEngine
from repro.serving.scheduler import Scheduler, SchedulerConfig

cfg = get_smoke_config("mistral-nemo-12b")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, 10 + 3 * uid) for uid in range(6)]

streams = {}
for backend in ("ref", "lean", "fixed"):
    eng = DecodeEngine(cfg, params, config=EngineConfig(
        max_batch=3, cache_len=96, attn_backend=backend, num_workers=8,
        paged=PagedConfig(enabled=True, page_size=16),
    ))
    sch = Scheduler(eng, SchedulerConfig(
        chunk_size=8, prefill_pack=2, token_budget=16, policy="fcfs",
    ))

    tokens_seen = {}
    def on_token(uid, tok, done, _acc=tokens_seen):
        _acc.setdefault(uid, []).append(tok)

    t0 = time.perf_counter()
    handles = [
        sch.submit(p, max_new_tokens=6, on_token=on_token, uid=uid)
        for uid, p in enumerate(prompts)
    ]
    sch.run_to_completion(max_steps=200)
    dt = time.perf_counter() - t0
    streams[backend] = [tuple(h.generated) for h in handles]

    tel = sch.telemetry()
    print(f"{backend:6s}: {tel['tokens_generated']} decode tokens + "
          f"{tel['admitted']} first tokens in {tel['steps']} steps "
          f"({dt:.2f}s); {tel['chunks']} prefill chunks "
          f"({tel['prefill_tokens']} prompt tokens streamed into the pool)")
    print(f"        TTFT p50={tel['ttft']['p50']*1e3:.1f}ms "
          f"p99={tel['ttft']['p99']*1e3:.1f}ms | "
          f"TPOT p50={tel['tpot']['p50']*1e3:.1f}ms | "
          f"queue wait p99={tel['queue_wait']['p99']*1e3:.1f}ms")
    assert all(tokens_seen[h.uid] == h.generated for h in handles)

assert streams["ref"] == streams["lean"] == streams["fixed"], \
    "backends diverged"
print("\nall backends token-identical; streaming callbacks matched handles")

# speculative decode: the prompt-lookup proposer drafts k tokens, ONE
# stream-K verify sweep scores all of them, and the accepted prefix lands
# in a single tick — output stays token-identical to plain greedy decode
eng = DecodeEngine(cfg, params, config=EngineConfig(
    max_batch=3, cache_len=96, attn_backend="lean", num_workers=8,
    paged=PagedConfig(enabled=True, page_size=16),
    spec=SpecConfig(enabled=True, k=4),
))
sch = Scheduler(eng, SchedulerConfig(
    chunk_size=8, prefill_pack=2, token_budget=16, policy="fcfs",
))
handles = [sch.submit(p, max_new_tokens=6, uid=uid)
           for uid, p in enumerate(prompts)]
sch.run_to_completion(max_steps=200)
assert [tuple(h.generated) for h in handles] == streams["lean"], \
    "speculative decode diverged from greedy"
tel = sch.telemetry()
print(f"spec  : identical stream in {tel['spec_ticks']} verify ticks; "
      f"{tel['spec_accepted_tokens']}/{tel['spec_draft_tokens']} drafts "
      f"accepted (rate {tel['spec_accept_rate']:.2f})")
