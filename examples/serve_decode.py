"""Serve a small model with continuously-batched requests through the
LeanAttention decode engine; compares all three attention backends.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.engine import DecodeEngine, Request

cfg = get_smoke_config("mistral-nemo-12b")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

for backend in ("ref", "lean", "fixed"):
    eng = DecodeEngine(cfg, params, max_batch=3, cache_len=96,
                       attn_backend=backend, num_workers=8)
    for uid in range(6):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 10 + 3 * uid),
                           max_new_tokens=8))
    t0 = time.perf_counter()
    stats = eng.run_to_completion(max_ticks=100)
    dt = time.perf_counter() - t0
    print(f"{backend:6s}: {stats.tokens_generated} tokens in {stats.ticks} "
          f"ticks ({dt:.2f}s), {stats.prefills} prefills")
    if eng.stats.schedules:
        s = eng.stats.schedules[-1]
        print(f"        last tick lean schedule: lens={s['lens']} "
              f"tiles={s['total_tiles']} pieces={s['pieces']}")
