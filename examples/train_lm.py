"""Train a ~small LM for a few hundred steps on CPU (reduced mistral-nemo
config family), with checkpoint/restart demonstrated mid-run.

  PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CKPT = Path("/tmp/repro_train_lm_ckpt")

if CKPT.exists():
    shutil.rmtree(CKPT)

base = [sys.executable, "-m", "repro.launch.train", "--arch",
        "mistral-nemo-12b", "--smoke", "--batch", "8", "--seq", "64",
        "--ckpt-dir", str(CKPT), "--ckpt-every", "50", "--log-every", "20"]
env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}

print("== phase 1: 100 steps ==")
subprocess.run(base + ["--steps", "100"], check=True, env=env)
print("== phase 2: resume (simulated restart) + 100 steps ==")
subprocess.run(base + ["--steps", "100"], check=True, env=env)
print("training with restart complete; checkpoints in", CKPT)
