"""Quickstart: LeanAttention in four acts.

  PYTHONPATH=src python examples/quickstart.py

1. The associative softmax re-scaling merge (the paper's theorem).
2. A stream-K LeanSchedule over a ragged decode batch.
3. The Pallas lean kernel vs the oracle (interpret mode on CPU).
4. FA2 / FlashDecoding recovered as special cases of the lean schedule.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    chunk_partial, finalize, make_schedule, merge, mha_decode_ref,
)
from repro.kernels import lean_decode

rng = np.random.default_rng(0)
B, Hq, Hkv, S, d = 2, 8, 4, 1000, 64
q = jnp.asarray(rng.standard_normal((B, Hq, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)

# --- 1. unequal chunks merge to exact attention -------------------------
scale = 1.0 / np.sqrt(d)
qg = q.reshape(B, Hkv, 2, d)
a = chunk_partial(qg, k[:, :, :137], v[:, :, :137], scale)
b = chunk_partial(qg, k[:, :, 137:], v[:, :, 137:], scale)
merged = finalize(merge(a, b)).reshape(B, Hq, d)
ref = mha_decode_ref(q, k, v)
print("1. unequal-chunk merge err:", float(jnp.max(jnp.abs(merged - ref))))

# --- 2. a ragged stream-K schedule ---------------------------------------
lens = [1000, 300]
sched = make_schedule(lens, Hkv, tile_size=128, num_workers=6)
print(f"2. ragged schedule: {sched.total_tiles} LeanTiles over "
      f"{sched.num_workers} workers x {sched.tiles_per_worker} tiles, "
      f"{sched.num_pieces} pieces to merge")

# --- 3. the Pallas stream-K kernel ---------------------------------------
out = lean_decode(q, k, v, lens, num_workers=6, tile=128, interpret=True)
ref_r = mha_decode_ref(q, k, v, ctx_lens=jnp.asarray(lens, jnp.int32))
print("3. lean kernel vs oracle err:", float(jnp.max(jnp.abs(out - ref_r))))

# --- 4. FA2 / FlashDecoding as special cases ------------------------------
segs = B * Hkv
for name, G in [("FA2-like (G=segments)", segs),
                ("FlashDecoding-like (G=2*segments)", 2 * segs),
                ("lean (G=hardware width)", 13)]:
    o = lean_decode(q, k, v, num_workers=G, tile=128, interpret=True)
    print(f"4. {name}: err={float(jnp.max(jnp.abs(o - ref))):.2e}")
